//! Deterministic, seedable RNG (SplitMix64 seeding a PCG32 stream).
//!
//! All stochastic choices in the framework — router index sampling, synthetic
//! task generation, adapter init fallback — flow through this module so every
//! experiment is reproducible from a `(seed, stream)` pair, matching the
//! paper's seed-averaged protocol (Appendix A.2, B.3).

/// PCG32 (XSH-RR 64/32) with SplitMix64 seed expansion.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// New RNG from a seed and a stream id (independent streams for the same
    /// seed never collide: PCG stream selection).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut s = seed;
        let state0 = splitmix64(&mut s);
        let mut t = stream.wrapping_mul(0xA0761D6478BD642F).wrapping_add(seed);
        let inc = splitmix64(&mut t) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = state0.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Derive a child RNG (for per-layer / per-block streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = (self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
            .wrapping_add(tag);
        Rng::new(seed, tag)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Lemire's unbiased method.
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let l = m as u32;
            if l >= n || l >= (u32::MAX - n + 1) % n {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()) as f64; // (0, 1]
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices sampled uniformly from [0, n) (k <= n),
    /// order randomized (partial Fisher-Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Vector of iid normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Vector of iid uniforms in [-bound, bound].
    pub fn uniform_vec(&mut self, n: usize, bound: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform(-bound, bound)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7, 0);
        let mut b = Rng::new(7, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::new(7, 0);
        let mut b = Rng::new(7, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(0, 0);
        let mut b = Rng::new(1, 0);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3, 0);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(11, 2);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5, 0);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(9, 0);
        for _ in 0..50 {
            let n = r.range(1, 30);
            let k = r.range(0, n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(2, 0);
        let mut xs: Vec<usize> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn fork_independent() {
        let mut parent = Rng::new(1, 0);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
