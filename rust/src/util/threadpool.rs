//! Fixed-size worker thread pool over std::sync primitives (tokio is not
//! vendored offline). The GEMM engine (`model::math::pool`) and the
//! coordinator's factor precompute share one process-global instance;
//! [`ThreadPool::scoped_map`] lets hot paths fan work out over *borrowed*
//! slices without `'static` bounds or per-job clones.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    static IN_POOL: Cell<bool> = Cell::new(false);
}

/// True when the current thread is a pool worker. Nested `scoped_map`
/// calls (a pool job fanning out onto its own pool) would deadlock a FIFO
/// queue once every worker is blocked waiting on queued sub-jobs, so
/// pool-aware callers use this to fall back to inline execution.
pub fn in_worker() -> bool {
    IN_POOL.with(|f| f.get())
}

/// A simple FIFO thread pool. Jobs submitted with [`ThreadPool::execute`]
/// run on one of `n` workers; dropping the pool joins all workers after the
/// queue drains.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("mos-worker-{i}"))
                    .spawn(move || {
                        IN_POOL.with(|f| f.set(true));
                        loop {
                            let job = rx.lock().unwrap().recv();
                            match job {
                                Ok(job) => job(),
                                Err(_) => break, // all senders dropped
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    fn execute_boxed(&self, job: Job) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .expect("workers alive");
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.execute_boxed(Box::new(f));
    }

    /// Run `f` over all items, collecting results in order. Alias for
    /// [`ThreadPool::scoped_map`] (kept for the original API; unlike the
    /// old channel-based version, a panicking job no longer kills a
    /// worker thread).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Send + Sync,
    {
        self.scoped_map(items, f)
    }

    /// Run `f` over all items on the pool, blocking until every job has
    /// finished, and collect results in submission order.
    ///
    /// Unlike [`ThreadPool::map`], items, results, and the closure may
    /// borrow from the caller's stack (no `'static` bound, no `Arc`/clone
    /// per job): the call does not return until all jobs completed, so the
    /// borrows stay valid for the jobs' whole lifetime. Called from inside
    /// a pool worker (nested fan-out) or with 0/1 items, it runs inline on
    /// the current thread instead of enqueueing.
    pub fn scoped_map<'scope, T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'scope,
        R: Send + 'scope,
        F: Fn(T) -> R + Send + Sync + 'scope,
    {
        if items.len() <= 1 || self.workers.len() <= 1 || in_worker() {
            return items.into_iter().map(f).collect();
        }
        struct ScopeState {
            done: Mutex<usize>,
            cvar: Condvar,
            panicked: AtomicBool,
        }
        let n = items.len();
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let state = ScopeState {
            done: Mutex::new(0),
            cvar: Condvar::new(),
            panicked: AtomicBool::new(false),
        };
        {
            let f = &f;
            let state_ref = &state;
            let out_addr = out.as_mut_ptr() as usize;
            for (i, item) in items.into_iter().enumerate() {
                let job: Box<dyn FnOnce() + Send + 'scope> =
                    Box::new(move || {
                        let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                        match r {
                            // SAFETY: slot i is written by exactly one job,
                            // and `out` outlives the wait loop below.
                            Ok(v) => unsafe {
                                *(out_addr as *mut Option<R>).add(i) = Some(v);
                            },
                            Err(_) => {
                                state_ref.panicked.store(true, Ordering::SeqCst)
                            }
                        }
                        let mut done = state_ref.done.lock().unwrap();
                        *done += 1;
                        state_ref.cvar.notify_all();
                    });
                // SAFETY: the wait loop below blocks until every job has
                // run, so the borrows captured by `job` ('scope) are live
                // for its whole execution; the lifetime is erased only to
                // pass through the 'static job channel.
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                self.execute_boxed(job);
            }
            let mut done = state.done.lock().unwrap();
            while *done < n {
                done = state.cvar.wait(done).unwrap();
            }
        }
        assert!(
            !state.panicked.load(Ordering::SeqCst),
            "scoped_map job panicked"
        );
        out.into_iter().map(|r| r.expect("job completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: usize| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_is_sequentialish() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x: i32| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn scoped_map_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let data: Vec<f32> = (0..64).map(|x| x as f32).collect();
        // closure borrows `data`; items borrow disjoint chunks of a local
        let mut sums = vec![0.0f32; 8];
        let chunks: Vec<(usize, &mut f32)> =
            sums.iter_mut().enumerate().collect();
        let out = pool.scoped_map(chunks, |(i, slot)| {
            let s: f32 = data[i * 8..(i + 1) * 8].iter().sum();
            *slot = s;
            i
        });
        assert_eq!(out, (0..8).collect::<Vec<_>>());
        for (i, s) in sums.iter().enumerate() {
            let want: f32 = (i * 8..(i + 1) * 8).map(|x| x as f32).sum();
            assert_eq!(*s, want);
        }
    }

    #[test]
    fn scoped_map_preserves_order_under_load() {
        let pool = ThreadPool::new(3);
        let out = pool.scoped_map((0..200).collect::<Vec<usize>>(), |x| x * 3);
        assert_eq!(out, (0..200).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_nested_runs_inline() {
        // a scoped_map job fanning out on the same pool must not deadlock
        let pool = Arc::new(ThreadPool::new(2));
        let p2 = Arc::clone(&pool);
        let out = pool.scoped_map(vec![10usize, 20, 30], move |x| {
            // in_worker() is set here, so this inner call runs inline
            p2.scoped_map(vec![x, x + 1], |y| y * 2).iter().sum::<usize>()
        });
        assert_eq!(out, vec![42, 82, 122]);
    }

    #[test]
    #[should_panic(expected = "scoped_map job panicked")]
    fn scoped_map_propagates_panics() {
        let pool = ThreadPool::new(2);
        pool.scoped_map(vec![0usize, 1, 2, 3], |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn worker_survives_scoped_panic() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_map(vec![0usize, 1, 2, 3], |_| panic!("boom"));
        }));
        assert!(r.is_err());
        // pool still functional afterwards
        let out = pool.scoped_map(vec![1usize, 2, 3, 4], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4, 5]);
    }
}
