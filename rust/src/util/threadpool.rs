//! Fixed-size worker thread pool over std::sync primitives (tokio is not
//! vendored offline; the coordinator uses this for its event loop workers).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple FIFO thread pool. Jobs submitted with [`ThreadPool::execute`]
/// run on one of `n` workers; dropping the pool joins all workers after the
/// queue drains.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("mos-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(tx), workers }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run `f` over all items, collecting results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: usize| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_is_sequentialish() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x: i32| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
