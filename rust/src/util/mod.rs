//! Shared substrates: RNG, JSON, weight-bank IO, CLI parsing, logging,
//! thread pool, and a tiny property-testing harness.
//!
//! The offline build image vendors only `xla` + `anyhow`, so these are
//! hand-rolled rather than pulled from crates.io (see DESIGN.md §1).

pub mod alloc;
pub mod bank;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod threadpool;
