//! Weight-bank container IO — the binary interchange format shared with
//! `python/compile/aot.py::write_bank` (magic `MOSBANK1`).
//!
//! Layout: `[8B magic][u32 n]` then per tensor:
//! `[u16 name_len][name][u8 dtype][u8 ndim][u32 dims...][raw LE data]`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"MOSBANK1";

/// A named host tensor (f32 or i32), row-major.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32s(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn i32s(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    /// Bytes of payload (for the memory ledger).
    pub fn nbytes(&self) -> usize {
        self.len() * 4
    }
}

/// Ordered name -> tensor map.
pub type Bank = BTreeMap<String, Tensor>;

pub fn read_bank(path: &Path) -> Result<Bank> {
    let buf = std::fs::read(path)
        .with_context(|| format!("reading bank {}", path.display()))?;
    parse_bank(&buf).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse_bank(buf: &[u8]) -> Result<Bank> {
    let mut r = buf;
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic {:?}", magic);
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = Bank::new();
    for _ in 0..n {
        let name_len = read_u16(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let count: usize = shape.iter().product();
        let mut raw = vec![0u8; count * 4];
        r.read_exact(&mut raw)?;
        let t = match dtype {
            0 => Tensor::F32 {
                shape,
                data: raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            1 => Tensor::I32 {
                shape,
                data: raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            d => bail!("tensor '{name}': unknown dtype {d}"),
        };
        out.insert(name, t);
    }
    Ok(out)
}

pub fn write_bank(path: &Path, bank: &Bank) -> Result<()> {
    let mut buf = Vec::new();
    buf.write_all(MAGIC)?;
    buf.write_all(&(bank.len() as u32).to_le_bytes())?;
    for (name, t) in bank {
        buf.write_all(&(name.len() as u16).to_le_bytes())?;
        buf.write_all(name.as_bytes())?;
        let (dtype, shape): (u8, &[usize]) = match t {
            Tensor::F32 { shape, .. } => (0, shape),
            Tensor::I32 { shape, .. } => (1, shape),
        };
        buf.write_all(&[dtype, shape.len() as u8])?;
        for &d in shape {
            buf.write_all(&(d as u32).to_le_bytes())?;
        }
        match t {
            Tensor::F32 { data, .. } => {
                for v in data {
                    buf.write_all(&v.to_le_bytes())?;
                }
            }
            Tensor::I32 { data, .. } => {
                for v in data {
                    buf.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    std::fs::write(path, buf)
        .with_context(|| format!("writing bank {}", path.display()))?;
    Ok(())
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut &[u8]) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut bank = Bank::new();
        bank.insert(
            "a.w".into(),
            Tensor::from_f32(&[2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-7, 9.0]),
        );
        bank.insert("idx".into(), Tensor::from_i32(&[4], vec![0, -1, 7, 3]));
        bank.insert("scalar".into(), Tensor::from_f32(&[1], vec![42.0]));
        let dir = std::env::temp_dir().join("mos_bank_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.bin");
        write_bank(&path, &bank).unwrap();
        let back = read_bank(&path).unwrap();
        assert_eq!(bank, back);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_bank(b"NOTABANKxxxx").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut bank = Bank::new();
        bank.insert("t".into(), Tensor::from_f32(&[8], vec![0.0; 8]));
        let dir = std::env::temp_dir().join("mos_bank_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        write_bank(&path, &bank).unwrap();
        let mut buf = std::fs::read(&path).unwrap();
        buf.truncate(buf.len() - 5);
        assert!(parse_bank(&buf).is_err());
    }
}
