//! Trainable-parameter accounting for every method on any geometry.
//!
//! Reproduces the paper's "# Param" column: on the LLaMA2-7B geometry,
//! LoRA r=2 -> 5.00M, r=8 -> 19.99M, r=16 -> 39.98M, r=64 -> 159.91M,
//! VeRA r=256 -> 1.42M, and MoS at budget e matches LoRA rank e exactly.
//! Also powers the intro's serving-memory claim (3.36 TB for 10k users of
//! rank-16 LoRA on a 70B model) and the fig_memory_scaling bench.

use crate::config::{Method, MethodCfg, ModelCfg, LAYER_TYPES};

/// Trainable parameters of an adapter on a model geometry.
pub fn trainable_params(cfg: &ModelCfg, mc: &MethodCfg) -> usize {
    let blocks = cfg.blocks;
    let mut total = 0usize;
    for t in LAYER_TYPES {
        let (o, i) = cfg.dims(t);
        total += match mc.method {
            Method::LoRA => blocks * mc.r * (i + o),
            // pools: n*(i/l) + n*(o/l) with n = e*L*l  ==  e*L*(i+o),
            // independent of both l and r (Sec. 3.1)
            Method::MoS => mc.e * blocks * (i + o),
            Method::VeRA => blocks * (mc.r + o),
            Method::Tied => mc.r * (i + o) + blocks * (mc.r + o),
            Method::PRoLoRA => blocks * mc.r * (i + o) / mc.m,
        };
    }
    total
}

/// Per-tenant *serving state* in bytes: what must sit in accelerator memory
/// to serve one customized model (paper intro scenario).
///
/// * LoRA-family: the dense per-block factors (fp16 = 2 bytes by default).
/// * MoS: the pools + the index matrices (i32) + rank scales — the whole
///   point of the paper: tenants share nothing here; each tenant's pools
///   are their own, but they are ~L× smaller than LoRA factors of equal
///   rank (and the indices are negligible).
pub fn serving_bytes(cfg: &ModelCfg, mc: &MethodCfg, bytes_per_param: usize) -> usize {
    let mut total = trainable_params(cfg, mc) * bytes_per_param;
    if mc.method == Method::MoS {
        // index matrices: 2 sides * L*r*l i32 per layer type + scales
        let idx = 2 * cfg.blocks * mc.r * mc.l * LAYER_TYPES.len() * 4;
        let scales = cfg.blocks * mc.r * LAYER_TYPES.len() * bytes_per_param;
        total += idx + scales;
    }
    if mc.method == Method::VeRA {
        // the frozen shared matrices are per-deployment, not per-tenant —
        // excluded, matching how VeRA reports parameter counts.
    }
    total
}

/// The intro's headline: GPU bytes for `tenants` concurrently-loaded
/// customized models (excluding the shared base model).
pub fn multi_tenant_bytes(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    tenants: usize,
    bytes_per_param: usize,
) -> usize {
    tenants * serving_bytes(cfg, mc, bytes_per_param)
}

/// Human-readable param count, paper-style ("5.00M").
pub fn fmt_params(n: usize) -> String {
    if n >= 1_000_000_000 {
        format!("{:.2}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.2}K", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

/// Human-readable bytes ("3.36 TB").
pub fn fmt_bytes(n: usize) -> String {
    let f = n as f64;
    if f >= 1e12 {
        format!("{:.2} TB", f / 1e12)
    } else if f >= 1e9 {
        format!("{:.2} GB", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2} MB", f / 1e6)
    } else {
        format!("{:.2} KB", f / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    /// Table 2 "# Param" column, digit-for-digit on LLaMA2-7B.
    #[test]
    fn table2_param_column_llama2_7b() {
        let cfg = presets::llama2_7b();
        let cases = [
            (MethodCfg::lora(2), 5.00),
            (MethodCfg::lora(8), 19.99),
            (MethodCfg::lora(16), 39.98),
            (MethodCfg::lora(64), 159.91),
            (MethodCfg::vera(256), 1.42),
            (MethodCfg::mos(8, 2, 2, 1), 5.00),   // "4/8" row
            (MethodCfg::mos(32, 2, 8, 1), 19.99), // "16/32" row
            (MethodCfg::prolora(8, 4), 5.00),     // "4/8" row
        ];
        for (mc, want_m) in cases {
            let got = trainable_params(&cfg, &mc) as f64 / 1e6;
            assert!(
                (got - want_m).abs() < 0.01,
                "{:?} r={}: got {got:.2}M want {want_m}M",
                mc.method,
                mc.r
            );
        }
    }

    #[test]
    fn mos_count_independent_of_r_and_l() {
        let cfg = presets::llama2_7b();
        let a = trainable_params(&cfg, &MethodCfg::mos(4, 1, 2, 0));
        let b = trainable_params(&cfg, &MethodCfg::mos(32, 8, 2, 1));
        assert_eq!(a, b);
    }

    #[test]
    fn mos_equals_lora_at_budget_rank() {
        for cfg in [presets::tiny(), presets::llama2_7b(), presets::llama32_3b()]
        {
            for e in [2usize, 8] {
                assert_eq!(
                    trainable_params(&cfg, &MethodCfg::mos(4 * e, 2, e, 1)),
                    trainable_params(&cfg, &MethodCfg::lora(e)),
                    "{} e={e}",
                    cfg.name
                );
            }
        }
    }

    /// Intro claim: 10,000 users x LoRA r=16 on a 70B model ≈ 3.36 TB
    /// (fp16). The paper's arithmetic: 10k * ~42M LoRA params * 2B * ...
    #[test]
    fn intro_memory_claim_70b() {
        let cfg = presets::llama2_70b();
        let lora16 = multi_tenant_bytes(&cfg, &MethodCfg::lora(16), 10_000, 2);
        let tb = lora16 as f64 / 1e12;
        // GQA shrinks k/v so the exact value depends on conventions; the
        // claim's order (a few TB) must hold.
        assert!((1.0..5.0).contains(&tb), "got {tb:.2} TB");
        // MoS at 8x savings serves the same population in ~1/8 the bytes
        let mos = multi_tenant_bytes(&cfg, &MethodCfg::mos(8, 2, 2, 1), 10_000, 2);
        let ratio = lora16 as f64 / mos as f64;
        assert!(ratio > 6.0, "MoS saving ratio {ratio:.1}");
    }

    #[test]
    fn llama32_3b_lora_param_count_matches_table4() {
        // Table 4: LoRA r=2 on LLaMA3.2-3B = 3.04M
        let cfg = presets::llama32_3b();
        let got = trainable_params(&cfg, &MethodCfg::lora(2)) as f64 / 1e6;
        assert!((got - 3.04).abs() < 0.03, "got {got:.2}M want 3.04M");
        // Table 5: LoRA r=8 = 12.16M, r=64 = 97.26M
        let r8 = trainable_params(&cfg, &MethodCfg::lora(8)) as f64 / 1e6;
        assert!((r8 - 12.16).abs() < 0.1, "got {r8:.2}M want 12.16M");
        let r64 = trainable_params(&cfg, &MethodCfg::lora(64)) as f64 / 1e6;
        assert!((r64 - 97.26).abs() < 0.5, "got {r64:.2}M want 97.26M");
    }

    #[test]
    fn serving_bytes_mos_overhead_is_small() {
        let cfg = presets::llama2_7b();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let params = trainable_params(&cfg, &mc) * 2;
        let serve = serving_bytes(&cfg, &mc, 2);
        let overhead = (serve - params) as f64 / params as f64;
        assert!(overhead < 0.01, "index overhead {overhead:.4}");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_params(4_997_120), "5.00M");
        assert_eq!(fmt_params(1_420_000_000), "1.42B");
        assert_eq!(fmt_bytes(3_360_000_000_000), "3.36 TB");
    }
}
