//! Adapter subsystem — the paper's core contribution and its baselines.
//!
//! * [`mos`] — global shard pools + the index-based router implementing the
//!   four differentiation strategies (subset selection, pair dissociation,
//!   vector sharding, shard privatization), plus host-side materialization
//!   and the combinatorial-diversity analysis of Appendix B.1.
//! * [`lora`], [`vera`], [`tied`], [`prolora`] — baseline methods
//!   (host-side init + per-block dense materialization).
//! * [`params`] — trainable-parameter accounting for every method on any
//!   geometry (reproduces Table 2's "# Param" column on LLaMA2-7B).
//!
//! All adapters share one currency: a [`Bank`] of named tensors whose names
//! match the AOT artifact input specs, so runtime binding is by name.

pub mod lora;
pub mod mos;
pub mod params;
pub mod prolora;
pub mod tied;
pub mod vera;

use crate::config::{Method, MethodCfg, ModelCfg, LAYER_TYPES};
use crate::util::bank::{Bank, Tensor};
use crate::util::rng::Rng;

/// Dense per-block low-rank factors for one layer type:
/// `a[k]` is (r, in) row-major, `b[k]` is (out, r) row-major.
#[derive(Debug, Clone)]
pub struct Factors {
    pub r: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    /// per block: r * in_dim
    pub a: Vec<Vec<f32>>,
    /// per block: out_dim * r
    pub b: Vec<Vec<f32>>,
}

impl Factors {
    /// Dense delta W = B A for block k: (out, in) row-major, computed
    /// through the shared GEMM engine (`B (o,r) @ A (r,i)`).
    pub fn delta(&self, k: usize) -> Vec<f32> {
        let (r, i, o) = (self.r, self.in_dim, self.out_dim);
        crate::model::math::matmul_nn(&self.b[k], &self.a[k], o, r, i)
    }
}

/// Initialize trainable adapter parameters host-side, matching the init
/// conventions of `python/compile/model.py::init_adapter` (B-side zero,
/// A-side uniform with materialized fan-in bounds). Used when running on the
/// host oracle runtime or when artifacts' init banks are absent.
pub fn init_params(cfg: &ModelCfg, mc: &MethodCfg, seed: u64) -> Bank {
    let mut rng = Rng::new(seed, 17);
    let mut bank = Bank::new();
    let lcount = cfg.blocks;
    for t in LAYER_TYPES {
        let (o, i) = cfg.dims(t);
        let r = mc.r;
        let bound = (1.0 / i as f32).sqrt();
        match mc.method {
            Method::LoRA => {
                bank.insert(
                    format!("{t}.a"),
                    Tensor::from_f32(
                        &[lcount, r, i],
                        rng.uniform_vec(lcount * r * i, bound),
                    ),
                );
                bank.insert(
                    format!("{t}.b"),
                    Tensor::zeros(&[lcount, o, r]),
                );
            }
            Method::MoS => {
                let n = mc.pool_shards(cfg.blocks);
                bank.insert(
                    format!("{t}.pool_a"),
                    Tensor::from_f32(
                        &[n, i / mc.l],
                        rng.uniform_vec(n * (i / mc.l), bound),
                    ),
                );
                bank.insert(
                    format!("{t}.pool_b"),
                    Tensor::zeros(&[n, o / mc.l]),
                );
            }
            Method::VeRA => {
                bank.insert(
                    format!("{t}.d"),
                    Tensor::from_f32(&[lcount, r], vec![0.1; lcount * r]),
                );
                bank.insert(
                    format!("{t}.bvec"),
                    Tensor::zeros(&[lcount, o]),
                );
            }
            Method::Tied => {
                bank.insert(
                    format!("{t}.a"),
                    Tensor::from_f32(&[r, i], rng.uniform_vec(r * i, bound)),
                );
                bank.insert(format!("{t}.b"), Tensor::zeros(&[o, r]));
                bank.insert(
                    format!("{t}.u"),
                    Tensor::from_f32(&[lcount, r], vec![0.1; lcount * r]),
                );
                bank.insert(
                    format!("{t}.v"),
                    Tensor::from_f32(&[lcount, o], vec![1.0; lcount * o]),
                );
            }
            Method::PRoLoRA => {
                let ic = i / mc.m;
                let oc = o / mc.m;
                bank.insert(
                    format!("{t}.a0"),
                    Tensor::from_f32(
                        &[lcount, r, ic],
                        rng.uniform_vec(lcount * r * ic, bound),
                    ),
                );
                bank.insert(
                    format!("{t}.b0"),
                    Tensor::zeros(&[lcount, oc, r]),
                );
            }
        }
    }
    bank
}

/// Materialize dense per-block factors for any method.
///
/// `aux` carries router state (MoS) or frozen matrices (VeRA); see
/// [`mos::router::build_router`] and [`vera::frozen_matrices`].
pub fn materialize(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    params: &Bank,
    aux: &Bank,
    layer_type: &str,
) -> Factors {
    match mc.method {
        Method::LoRA => lora::materialize(cfg, mc, params, layer_type),
        Method::MoS => mos::materialize::factors(cfg, mc, params, aux, layer_type),
        Method::VeRA => vera::materialize(cfg, mc, params, aux, layer_type),
        Method::Tied => tied::materialize(cfg, mc, params, layer_type),
        Method::PRoLoRA => prolora::materialize(cfg, mc, params, layer_type),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn init_shapes_cover_all_layer_types() {
        let cfg = presets::tiny();
        for mc in [
            MethodCfg::lora(2),
            MethodCfg::mos(8, 2, 2, 1),
            MethodCfg::vera(4),
            MethodCfg::tied(4),
            MethodCfg::prolora(8, 4),
        ] {
            let bank = init_params(&cfg, &mc, 0);
            // every layer type contributes at least one tensor
            for t in LAYER_TYPES {
                assert!(
                    bank.keys().any(|k| k.starts_with(&format!("{t}."))),
                    "{:?} missing tensors for {t}",
                    mc.method
                );
            }
        }
    }

    #[test]
    fn factors_delta_is_zero_at_init() {
        // B-side zero init => delta == 0 for every method (paper Sec. 3.5)
        let cfg = presets::tiny();
        for mc in [
            MethodCfg::lora(2),
            MethodCfg::mos(8, 2, 2, 1),
            MethodCfg::vera(4),
            MethodCfg::tied(4),
            MethodCfg::prolora(8, 4),
        ] {
            let params = init_params(&cfg, &mc, 0);
            let aux = match mc.method {
                Method::MoS => mos::router::build_router(&cfg, &mc, 0).into_bank(),
                Method::VeRA => vera::frozen_matrices(&cfg, &mc, 0),
                _ => Bank::new(),
            };
            let f = materialize(&cfg, &mc, &params, &aux, "q");
            for k in 0..cfg.blocks {
                assert!(
                    f.delta(k).iter().all(|&x| x == 0.0),
                    "{:?} nonzero delta at init",
                    mc.method
                );
            }
        }
    }

    #[test]
    fn factors_delta_matmul_correct() {
        // delta == B @ A checked against a straightforward triple loop
        let f = Factors {
            r: 2,
            in_dim: 3,
            out_dim: 2,
            a: vec![vec![1., 2., 3., 4., 5., 6.]], // (2,3)
            b: vec![vec![1., 0., 0., 2.]],         // (2,2)
        };
        let d = f.delta(0);
        // row0 = 1*a0 = [1,2,3]; row1 = 2*a1 = [8,10,12]
        assert_eq!(d, vec![1., 2., 3., 8., 10., 12.]);
    }
}
