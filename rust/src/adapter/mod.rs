//! Adapter subsystem — the paper's core contribution and its baselines.
//!
//! * [`mos`] — global shard pools + the index-based router implementing the
//!   four differentiation strategies (subset selection, pair dissociation,
//!   vector sharding, shard privatization), plus host-side materialization
//!   and the combinatorial-diversity analysis of Appendix B.1.
//! * [`lora`], [`vera`], [`tied`], [`prolora`] — baseline methods
//!   (host-side init + per-block dense materialization).
//! * [`params`] — trainable-parameter accounting for every method on any
//!   geometry (reproduces Table 2's "# Param" column on LLaMA2-7B).
//!
//! All adapters share one currency: a [`Bank`] of named tensors whose names
//! match the AOT artifact input specs, so runtime binding is by name.

pub mod lora;
pub mod mos;
pub mod params;
pub mod prolora;
pub mod tied;
pub mod vera;

use crate::config::{Method, MethodCfg, ModelCfg, LAYER_TYPES};
use crate::model::quant::QuantPool;
use crate::util::bank::{Bank, Tensor};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Dense per-block low-rank factors for one layer type:
/// `a[k]` is (r, in) row-major, `b[k]` is (out, r) row-major.
#[derive(Debug, Clone)]
pub struct Factors {
    pub r: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    /// per block: r * in_dim
    pub a: Vec<Vec<f32>>,
    /// per block: out_dim * r
    pub b: Vec<Vec<f32>>,
}

impl Factors {
    /// Dense delta W = B A for block k: (out, in) row-major, computed
    /// through the shared GEMM engine (`B (o,r) @ A (r,i)`).
    pub fn delta(&self, k: usize) -> Vec<f32> {
        let (r, i, o) = (self.r, self.in_dim, self.out_dim);
        crate::model::math::matmul_nn(&self.b[k], &self.a[k], o, r, i)
    }
}

/// Initialize trainable adapter parameters host-side, matching the init
/// conventions of `python/compile/model.py::init_adapter` (B-side zero,
/// A-side uniform with materialized fan-in bounds). Used when running on the
/// host oracle runtime or when artifacts' init banks are absent.
pub fn init_params(cfg: &ModelCfg, mc: &MethodCfg, seed: u64) -> Bank {
    let mut rng = Rng::new(seed, 17);
    let mut bank = Bank::new();
    let lcount = cfg.blocks;
    for t in LAYER_TYPES {
        let (o, i) = cfg.dims(t);
        let r = mc.r;
        let bound = (1.0 / i as f32).sqrt();
        match mc.method {
            Method::LoRA => {
                bank.insert(
                    format!("{t}.a"),
                    Tensor::from_f32(
                        &[lcount, r, i],
                        rng.uniform_vec(lcount * r * i, bound),
                    ),
                );
                bank.insert(
                    format!("{t}.b"),
                    Tensor::zeros(&[lcount, o, r]),
                );
            }
            Method::MoS => {
                let n = mc.pool_shards(cfg.blocks);
                bank.insert(
                    format!("{t}.pool_a"),
                    Tensor::from_f32(
                        &[n, i / mc.l],
                        rng.uniform_vec(n * (i / mc.l), bound),
                    ),
                );
                bank.insert(
                    format!("{t}.pool_b"),
                    Tensor::zeros(&[n, o / mc.l]),
                );
            }
            Method::VeRA => {
                bank.insert(
                    format!("{t}.d"),
                    Tensor::from_f32(&[lcount, r], vec![0.1; lcount * r]),
                );
                bank.insert(
                    format!("{t}.bvec"),
                    Tensor::zeros(&[lcount, o]),
                );
            }
            Method::Tied => {
                bank.insert(
                    format!("{t}.a"),
                    Tensor::from_f32(&[r, i], rng.uniform_vec(r * i, bound)),
                );
                bank.insert(format!("{t}.b"), Tensor::zeros(&[o, r]));
                bank.insert(
                    format!("{t}.u"),
                    Tensor::from_f32(&[lcount, r], vec![0.1; lcount * r]),
                );
                bank.insert(
                    format!("{t}.v"),
                    Tensor::from_f32(&[lcount, o], vec![1.0; lcount * o]),
                );
            }
            Method::PRoLoRA => {
                let ic = i / mc.m;
                let oc = o / mc.m;
                bank.insert(
                    format!("{t}.a0"),
                    Tensor::from_f32(
                        &[lcount, r, ic],
                        rng.uniform_vec(lcount * r * ic, bound),
                    ),
                );
                bank.insert(
                    format!("{t}.b0"),
                    Tensor::zeros(&[lcount, oc, r]),
                );
            }
        }
    }
    bank
}

/// Materialize dense per-block factors for any method.
///
/// `aux` carries router state (MoS) or frozen matrices (VeRA); see
/// [`mos::router::build_router`] and [`vera::frozen_matrices`].
pub fn materialize(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    params: &Bank,
    aux: &Bank,
    layer_type: &str,
) -> Factors {
    match mc.method {
        Method::LoRA => lora::materialize(cfg, mc, params, layer_type),
        Method::MoS => mos::materialize::factors(cfg, mc, params, aux, layer_type),
        Method::VeRA => vera::materialize(cfg, mc, params, aux, layer_type),
        Method::Tied => tied::materialize(cfg, mc, params, layer_type),
        Method::PRoLoRA => prolora::materialize(cfg, mc, params, layer_type),
    }
}

// ---------------------------------------------------------------------------
// serving representations
// ---------------------------------------------------------------------------

/// Per-layer-type tensor names of the pooled representation, precomputed
/// at build time so the serving hot path never formats a key string.
#[derive(Debug)]
struct PooledKeys {
    pool_a: String,
    pool_b: String,
    idx_a: String,
    idx_b: String,
    rank_scale: String,
}

/// Borrowed per-layer-type view into a [`PooledAdapter`]: the raw pool /
/// index / scale slices `gemm_gather_canon` consumes. Per-block slicing
/// (`idx_*[k*r*l..]`, `rank_scale[k*r..]`) is the caller's.
#[derive(Debug, Clone, Copy)]
pub struct PooledView<'a> {
    /// A-side shard pool, `(n, in/l)` row-major.
    pub pool_a: &'a [f32],
    /// B-side shard pool, `(n, out/l)` row-major.
    pub pool_b: &'a [f32],
    /// `(blocks, r, l)` shard indices into `pool_a`.
    pub idx_a: &'a [i32],
    /// `(blocks, r, l)` shard indices into `pool_b`.
    pub idx_b: &'a [i32],
    /// `(blocks, r)` per-rank scale, folded into the A side.
    pub rank_scale: &'a [f32],
    /// A-shard width `in/l`.
    pub shard_w_a: usize,
    /// B-shard width `out/l`.
    pub shard_w_b: usize,
}

/// The pooled serving representation of one MoS tenant: `Arc`s into the
/// registry's own param/aux banks (zero copy — adapter residency stays
/// O(pool + index tables), never the materialized dense size).
#[derive(Debug)]
pub struct PooledAdapter {
    pub mc: MethodCfg,
    params: Arc<Bank>,
    aux: Arc<Bank>,
    /// Parallel to [`LAYER_TYPES`].
    keys: Vec<PooledKeys>,
}

impl PooledAdapter {
    /// Wrap a tenant's banks; validates the geometry is MoS and every
    /// layer type's pool/index/scale tensors are present up front, so
    /// [`PooledAdapter::view`] can index infallibly on the hot path.
    pub fn new(mc: MethodCfg, params: Arc<Bank>, aux: Arc<Bank>) -> Result<PooledAdapter> {
        if mc.method != Method::MoS {
            bail!("pooled serving representation requires MoS, got {:?}", mc.method);
        }
        let keys: Vec<PooledKeys> = LAYER_TYPES
            .iter()
            .map(|t| PooledKeys {
                pool_a: format!("{t}.pool_a"),
                pool_b: format!("{t}.pool_b"),
                idx_a: format!("{t}.idx_a"),
                idx_b: format!("{t}.idx_b"),
                rank_scale: format!("{t}.rank_scale"),
            })
            .collect();
        for k in &keys {
            for (bank, name, which) in [
                (&params, &k.pool_a, "params"),
                (&params, &k.pool_b, "params"),
            ] {
                if bank.get(name).and_then(|t| t.f32s()).is_none() {
                    bail!("pooled adapter: missing f32 tensor '{name}' in {which}");
                }
            }
            for name in [&k.idx_a, &k.idx_b] {
                if aux.get(name).and_then(|t| t.i32s()).is_none() {
                    bail!("pooled adapter: missing i32 tensor '{name}' in aux");
                }
            }
            if aux.get(&k.rank_scale).and_then(|t| t.f32s()).is_none() {
                bail!("pooled adapter: missing f32 tensor '{}' in aux", k.rank_scale);
            }
        }
        Ok(PooledAdapter { mc, params, aux, keys })
    }

    /// The raw pooled slices for one layer type (`"q"`, `"gate"`, ...).
    pub fn view(&self, layer_type: &str) -> PooledView<'_> {
        let ti = LAYER_TYPES
            .iter()
            .position(|t| *t == layer_type)
            .unwrap_or_else(|| panic!("unknown layer type '{layer_type}'"));
        let k = &self.keys[ti];
        let pool_a = &self.params[&k.pool_a];
        let pool_b = &self.params[&k.pool_b];
        PooledView {
            shard_w_a: pool_a.shape()[1],
            shard_w_b: pool_b.shape()[1],
            pool_a: pool_a.f32s().unwrap(),
            pool_b: pool_b.f32s().unwrap(),
            idx_a: self.aux[&k.idx_a].i32s().unwrap(),
            idx_b: self.aux[&k.idx_b].i32s().unwrap(),
            rank_scale: self.aux[&k.rank_scale].f32s().unwrap(),
        }
    }

    /// Bytes actually resident for this representation: the shared-pool
    /// params plus the index/scale tables — exactly what
    /// [`params::serving_bytes`]`(cfg, mc, 4)` models analytically.
    pub fn resident_bytes(&self) -> usize {
        self.params.values().map(|t| t.nbytes()).sum::<usize>()
            + self.aux.values().map(|t| t.nbytes()).sum::<usize>()
    }
}

/// Borrowed per-layer-type view into a [`QuantPooledAdapter`]: int8
/// shard pools plus the same f32/i32 index and scale tables the f32
/// [`PooledView`] carries. Per-block slicing is the caller's, as there.
#[derive(Debug, Clone, Copy)]
pub struct QuantPooledView<'a> {
    /// A-side shard pool, `(n, in/l)` int8 codes + per-shard scales.
    pub pool_a: &'a QuantPool,
    /// B-side shard pool, `(n, out/l)` int8 codes + per-shard scales.
    pub pool_b: &'a QuantPool,
    /// `(blocks, r, l)` shard indices into `pool_a`.
    pub idx_a: &'a [i32],
    /// `(blocks, r, l)` shard indices into `pool_b`.
    pub idx_b: &'a [i32],
    /// `(blocks, r)` per-rank scale, folded into the A side.
    pub rank_scale: &'a [f32],
}

/// The int8 serving representation of one MoS tenant
/// (`MOS_SERVE_INT8=1`): the shard pools quantized once per tenant
/// version (per-shard symmetric scales, built from the *same* registry
/// pools the f32 [`PooledAdapter`] serves), while the index tables and
/// rank scales stay shared with the registry's aux bank. Residency drops
/// to ~1/4 of the f32 pool bytes (codes are 1 byte + one f32 scale per
/// shard row).
#[derive(Debug)]
pub struct QuantPooledAdapter {
    pub mc: MethodCfg,
    aux: Arc<Bank>,
    /// Parallel to [`LAYER_TYPES`]: quantized (pool_a, pool_b).
    pools: Vec<(QuantPool, QuantPool)>,
    /// Parallel to [`LAYER_TYPES`].
    keys: Vec<PooledKeys>,
}

impl QuantPooledAdapter {
    /// Quantize an f32 pooled adapter's shard pools (index/scale tables
    /// are shared, not copied). One pass per layer type at build time —
    /// the serving hot path only ever reads the codes.
    pub fn quantize(p: &PooledAdapter) -> QuantPooledAdapter {
        let pools = LAYER_TYPES
            .iter()
            .map(|t| {
                let v = p.view(t);
                (
                    QuantPool::quantize(v.shard_w_a, v.pool_a),
                    QuantPool::quantize(v.shard_w_b, v.pool_b),
                )
            })
            .collect();
        let keys = LAYER_TYPES
            .iter()
            .map(|t| PooledKeys {
                pool_a: format!("{t}.pool_a"),
                pool_b: format!("{t}.pool_b"),
                idx_a: format!("{t}.idx_a"),
                idx_b: format!("{t}.idx_b"),
                rank_scale: format!("{t}.rank_scale"),
            })
            .collect();
        QuantPooledAdapter {
            mc: p.mc.clone(),
            aux: Arc::clone(&p.aux),
            pools,
            keys,
        }
    }

    /// The int8 pooled slices for one layer type (`"q"`, `"gate"`, ...).
    pub fn view(&self, layer_type: &str) -> QuantPooledView<'_> {
        let ti = LAYER_TYPES
            .iter()
            .position(|t| *t == layer_type)
            .unwrap_or_else(|| panic!("unknown layer type '{layer_type}'"));
        let k = &self.keys[ti];
        let (pool_a, pool_b) = &self.pools[ti];
        QuantPooledView {
            pool_a,
            pool_b,
            idx_a: self.aux[&k.idx_a].i32s().unwrap(),
            idx_b: self.aux[&k.idx_b].i32s().unwrap(),
            rank_scale: self.aux[&k.rank_scale].f32s().unwrap(),
        }
    }

    /// Measured resident bytes: int8 pool codes + per-shard f32 scales,
    /// plus the shared index/scale tables (unchanged from f32 serving).
    /// The registry's analytic int8 model must equal this exactly
    /// (enforced by test).
    pub fn resident_bytes(&self) -> usize {
        self.pools
            .iter()
            .map(|(a, b)| a.nbytes() + b.nbytes())
            .sum::<usize>()
            + self.aux.values().map(|t| t.nbytes()).sum::<usize>()
    }
}

/// What the serving stack hands the model per tenant: the legacy dense
/// per-block factors (training parity / non-MoS methods /
/// `MOS_SERVE_DENSE=1`), the pooled zero-copy representation the
/// shard-gather GEMM path consumes directly, or its int8 twin
/// (`MOS_SERVE_INT8=1`). Cheap to clone (all arms are `Arc`s).
#[derive(Debug, Clone)]
pub enum ServingAdapter {
    /// Dense per-block factors for every layer type (materialized size).
    Dense(Arc<BTreeMap<String, Factors>>),
    /// Shard pools + index tables, shared with the registry (pool size).
    Pooled(Arc<PooledAdapter>),
    /// Int8 shard pools + shared index tables (~pool size / 4).
    PooledInt8(Arc<QuantPooledAdapter>),
}

impl ServingAdapter {
    /// Bytes of adapter state this representation keeps resident.
    pub fn resident_bytes(&self) -> usize {
        match self {
            ServingAdapter::Dense(f) => f
                .values()
                .map(|f| {
                    let floats: usize = f.a.iter().map(Vec::len).sum::<usize>()
                        + f.b.iter().map(Vec::len).sum::<usize>();
                    floats * 4
                })
                .sum(),
            ServingAdapter::Pooled(p) => p.resident_bytes(),
            ServingAdapter::PooledInt8(p) => p.resident_bytes(),
        }
    }

    /// The dense factors, when this is the dense representation.
    pub fn dense(&self) -> Option<&BTreeMap<String, Factors>> {
        match self {
            ServingAdapter::Dense(f) => Some(f),
            _ => None,
        }
    }

    /// The pooled adapter, when this is the f32 pooled representation.
    pub fn pooled(&self) -> Option<&PooledAdapter> {
        match self {
            ServingAdapter::Pooled(p) => Some(p),
            _ => None,
        }
    }

    /// The int8 pooled adapter, when this is the int8 representation.
    pub fn pooled_int8(&self) -> Option<&QuantPooledAdapter> {
        match self {
            ServingAdapter::PooledInt8(p) => Some(p),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn init_shapes_cover_all_layer_types() {
        let cfg = presets::tiny();
        for mc in [
            MethodCfg::lora(2),
            MethodCfg::mos(8, 2, 2, 1),
            MethodCfg::vera(4),
            MethodCfg::tied(4),
            MethodCfg::prolora(8, 4),
        ] {
            let bank = init_params(&cfg, &mc, 0);
            // every layer type contributes at least one tensor
            for t in LAYER_TYPES {
                assert!(
                    bank.keys().any(|k| k.starts_with(&format!("{t}."))),
                    "{:?} missing tensors for {t}",
                    mc.method
                );
            }
        }
    }

    #[test]
    fn factors_delta_is_zero_at_init() {
        // B-side zero init => delta == 0 for every method (paper Sec. 3.5)
        let cfg = presets::tiny();
        for mc in [
            MethodCfg::lora(2),
            MethodCfg::mos(8, 2, 2, 1),
            MethodCfg::vera(4),
            MethodCfg::tied(4),
            MethodCfg::prolora(8, 4),
        ] {
            let params = init_params(&cfg, &mc, 0);
            let aux = match mc.method {
                Method::MoS => mos::router::build_router(&cfg, &mc, 0).into_bank(),
                Method::VeRA => vera::frozen_matrices(&cfg, &mc, 0),
                _ => Bank::new(),
            };
            let f = materialize(&cfg, &mc, &params, &aux, "q");
            for k in 0..cfg.blocks {
                assert!(
                    f.delta(k).iter().all(|&x| x == 0.0),
                    "{:?} nonzero delta at init",
                    mc.method
                );
            }
        }
    }

    #[test]
    fn pooled_resident_bytes_equal_serving_bytes() {
        // the acceptance contract: what the pooled representation keeps
        // resident per tenant is exactly the analytic serving_bytes model
        // (pool + index tables), not the materialized dense size
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let params = Arc::new(init_params(&cfg, &mc, 0));
        let aux = Arc::new(mos::router::build_router(&cfg, &mc, 0).into_bank());
        let pooled =
            PooledAdapter::new(mc.clone(), params.clone(), aux.clone()).unwrap();
        assert_eq!(
            pooled.resident_bytes(),
            params::serving_bytes(&cfg, &mc, 4),
            "pooled residency drifted from the analytic model"
        );
        // the dense representation of the same tenant is several times
        // bigger (the whole point of serving from the pool)
        let dense: BTreeMap<String, Factors> = LAYER_TYPES
            .iter()
            .map(|t| {
                (t.to_string(), materialize(&cfg, &mc, &params, &aux, t))
            })
            .collect();
        let dense = ServingAdapter::Dense(Arc::new(dense));
        let pooled = ServingAdapter::Pooled(Arc::new(pooled));
        // r/e = 4 here; the index tables eat a little of the gap
        assert!(
            dense.resident_bytes() > 3 * pooled.resident_bytes(),
            "dense {} B vs pooled {} B: expected a large gap",
            dense.resident_bytes(),
            pooled.resident_bytes()
        );
    }

    #[test]
    fn pooled_view_shapes_match_geometry() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(4, 2, 2, 0);
        let params = Arc::new(init_params(&cfg, &mc, 3));
        let aux = Arc::new(mos::router::build_router(&cfg, &mc, 3).into_bank());
        let p = PooledAdapter::new(mc.clone(), params, aux).unwrap();
        for t in LAYER_TYPES {
            let (o, i) = cfg.dims(t);
            let v = p.view(t);
            assert_eq!(v.shard_w_a, i / mc.l, "{t} A shard width");
            assert_eq!(v.shard_w_b, o / mc.l, "{t} B shard width");
            assert_eq!(v.idx_a.len(), cfg.blocks * mc.r * mc.l, "{t} idx_a");
            assert_eq!(v.idx_b.len(), cfg.blocks * mc.r * mc.l, "{t} idx_b");
            assert_eq!(v.rank_scale.len(), cfg.blocks * mc.r, "{t} scale");
        }
    }

    #[test]
    fn quant_pooled_resident_bytes_match_analytic_model() {
        // the int8 ledger contract: measured residency is exactly
        // 1 byte/element + 4 bytes/shard-row over the params pools, plus
        // the aux tables unchanged — the formula the registry charges
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let params = Arc::new(init_params(&cfg, &mc, 0));
        let aux = Arc::new(mos::router::build_router(&cfg, &mc, 0).into_bank());
        let pooled =
            PooledAdapter::new(mc.clone(), params.clone(), aux.clone()).unwrap();
        let q = QuantPooledAdapter::quantize(&pooled);
        let analytic: usize = params
            .values()
            .map(|t| t.len() + 4 * t.shape()[0])
            .sum::<usize>()
            + aux.values().map(|t| t.nbytes()).sum::<usize>();
        assert_eq!(q.resident_bytes(), analytic);
        // the quantized pools themselves sit near 1/4 of the f32 pools
        let aux_bytes: usize = aux.values().map(|t| t.nbytes()).sum();
        let f32_pools: usize = params.values().map(|t| t.nbytes()).sum();
        let q_pools = q.resident_bytes() - aux_bytes;
        assert!(
            q_pools * 100 <= f32_pools * 35,
            "int8 pools {q_pools} B vs f32 pools {f32_pools} B: > 0.35x"
        );
        // views share the registry's index/scale tables byte-for-byte
        for t in LAYER_TYPES {
            let vf = pooled.view(t);
            let vq = q.view(t);
            assert_eq!(vq.pool_a.shard_w, vf.shard_w_a, "{t} A shard width");
            assert_eq!(vq.pool_b.shard_w, vf.shard_w_b, "{t} B shard width");
            assert_eq!(vq.idx_a, vf.idx_a, "{t} idx_a");
            assert_eq!(vq.idx_b, vf.idx_b, "{t} idx_b");
            assert_eq!(vq.rank_scale, vf.rank_scale, "{t} rank_scale");
        }
    }

    #[test]
    fn pooled_rejects_non_mos_geometry() {
        let cfg = presets::tiny();
        let mc = MethodCfg::lora(4);
        let params = Arc::new(init_params(&cfg, &mc, 0));
        let aux = Arc::new(Bank::new());
        assert!(PooledAdapter::new(mc, params, aux).is_err());
    }

    #[test]
    fn factors_delta_matmul_correct() {
        // delta == B @ A checked against a straightforward triple loop
        let f = Factors {
            r: 2,
            in_dim: 3,
            out_dim: 2,
            a: vec![vec![1., 2., 3., 4., 5., 6.]], // (2,3)
            b: vec![vec![1., 0., 0., 2.]],         // (2,2)
        };
        let d = f.delta(0);
        // row0 = 1*a0 = [1,2,3]; row1 = 2*a1 = [8,10,12]
        assert_eq!(d, vec![1., 2., 3., 8., 10., 12.]);
    }
}
