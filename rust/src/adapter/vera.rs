//! VeRA baseline (Kopiczko et al., 2023): frozen random shared matrices
//! A (r,in), B (out,r) per layer type + trainable per-block scaling vectors
//! d (L,r) and b (L,out). ΔW^k = Λ_b^k B Λ_d^k A.

use super::Factors;
use crate::config::{MethodCfg, ModelCfg, LAYER_TYPES};
use crate::util::bank::{Bank, Tensor};
use crate::util::rng::Rng;

/// Generate the frozen shared matrices (host-side twin of
/// `python/compile/aot.py::gen_frozen_aux`). Stored in the aux bank under
/// `<t>.frozen_a` / `<t>.frozen_b`.
pub fn frozen_matrices(cfg: &ModelCfg, mc: &MethodCfg, seed: u64) -> Bank {
    let mut rng = Rng::new(seed, 31);
    let mut bank = Bank::new();
    for t in LAYER_TYPES {
        let (o, i) = cfg.dims(t);
        let r = mc.r;
        bank.insert(
            format!("{t}.frozen_a"),
            Tensor::from_f32(&[r, i], rng.normal_vec(r * i, (i as f32).powf(-0.5))),
        );
        bank.insert(
            format!("{t}.frozen_b"),
            Tensor::from_f32(&[o, r], rng.normal_vec(o * r, (r as f32).powf(-0.5))),
        );
    }
    bank
}

pub fn materialize(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    params: &Bank,
    aux: &Bank,
    layer_type: &str,
) -> Factors {
    let (o, i) = cfg.dims(layer_type);
    let r = mc.r;
    let fa = aux[&format!("{layer_type}.frozen_a")].f32s().unwrap();
    let fb = aux[&format!("{layer_type}.frozen_b")].f32s().unwrap();
    let d = params[&format!("{layer_type}.d")].f32s().unwrap();
    let bv = params[&format!("{layer_type}.bvec")].f32s().unwrap();
    let mut a = Vec::with_capacity(cfg.blocks);
    let mut b = Vec::with_capacity(cfg.blocks);
    for k in 0..cfg.blocks {
        let mut ak = fa.to_vec();
        for rr in 0..r {
            let s = d[k * r + rr];
            for v in &mut ak[rr * i..(rr + 1) * i] {
                *v *= s;
            }
        }
        let mut bk = fb.to_vec();
        for oo in 0..o {
            let s = bv[k * o + oo];
            for v in &mut bk[oo * r..(oo + 1) * r] {
                *v *= s;
            }
        }
        a.push(ak);
        b.push(bk);
    }
    Factors { r, in_dim: i, out_dim: o, a, b }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::init_params;
    use crate::config::presets;

    #[test]
    fn shared_matrices_scaled_per_block() {
        let cfg = presets::tiny();
        let mc = MethodCfg::vera(4);
        let mut params = init_params(&cfg, &mc, 0);
        let aux = frozen_matrices(&cfg, &mc, 0);
        // give block 0 a distinctive d
        let key = "q.d".to_string();
        let t = params[&key].clone();
        let mut d = t.f32s().unwrap().to_vec();
        d[0] = 2.0; // block 0, rank 0
        params.insert(key, Tensor::from_f32(t.shape(), d));
        let f = materialize(&cfg, &mc, &params, &aux, "q");
        let fa = aux["q.frozen_a"].f32s().unwrap();
        let i = cfg.dims("q").1;
        // block 0 rank-0 row == 2 * frozen row; block 1 == 0.1 * frozen
        for c in 0..i {
            assert!((f.a[0][c] - 2.0 * fa[c]).abs() < 1e-6);
            assert!((f.a[1][c] - 0.1 * fa[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn frozen_deterministic() {
        let cfg = presets::tiny();
        let mc = MethodCfg::vera(4);
        assert_eq!(frozen_matrices(&cfg, &mc, 1), frozen_matrices(&cfg, &mc, 1));
        assert_ne!(frozen_matrices(&cfg, &mc, 1), frozen_matrices(&cfg, &mc, 2));
    }
}
