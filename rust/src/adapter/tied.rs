//! Tied-LoRA baseline (Renduchintala et al., 2023): shared *trainable*
//! low-rank matrices across blocks + per-block trainable scaling vectors.
//! ΔW^k = Λ_v^k B Λ_u^k A.

use super::Factors;
use crate::config::{MethodCfg, ModelCfg};
use crate::util::bank::Bank;

pub fn materialize(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    params: &Bank,
    layer_type: &str,
) -> Factors {
    let (o, i) = cfg.dims(layer_type);
    let r = mc.r;
    let sa = params[&format!("{layer_type}.a")].f32s().unwrap();
    let sb = params[&format!("{layer_type}.b")].f32s().unwrap();
    let u = params[&format!("{layer_type}.u")].f32s().unwrap();
    let v = params[&format!("{layer_type}.v")].f32s().unwrap();
    let mut a = Vec::with_capacity(cfg.blocks);
    let mut b = Vec::with_capacity(cfg.blocks);
    for k in 0..cfg.blocks {
        let mut ak = sa.to_vec();
        for rr in 0..r {
            let s = u[k * r + rr];
            for val in &mut ak[rr * i..(rr + 1) * i] {
                *val *= s;
            }
        }
        let mut bk = sb.to_vec();
        for oo in 0..o {
            let s = v[k * o + oo];
            for val in &mut bk[oo * r..(oo + 1) * r] {
                *val *= s;
            }
        }
        a.push(ak);
        b.push(bk);
    }
    Factors { r, in_dim: i, out_dim: o, a, b }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::init_params;
    use crate::config::presets;

    #[test]
    fn blocks_share_up_to_scale() {
        let cfg = presets::tiny();
        let mc = MethodCfg::tied(2);
        let params = init_params(&cfg, &mc, 0);
        let f = materialize(&cfg, &mc, &params, "q");
        // init: u = 0.1 everywhere -> identical A across blocks
        assert_eq!(f.a[0], f.a[1]);
        let i = cfg.dims("q").1;
        let sa = params["q.a"].f32s().unwrap();
        for c in 0..i {
            assert!((f.a[0][c] - 0.1 * sa[c]).abs() < 1e-6);
        }
    }
}
