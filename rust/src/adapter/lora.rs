//! Vanilla LoRA baseline (Hu et al., 2021): per-block trainable A (L,r,in)
//! and B (L,out,r), applied to all seven projection types (QLoRA setting).

use super::Factors;
use crate::config::{MethodCfg, ModelCfg};
use crate::util::bank::Bank;

/// Slice the stacked per-block tensors into dense factors.
pub fn materialize(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    params: &Bank,
    layer_type: &str,
) -> Factors {
    let (o, i) = cfg.dims(layer_type);
    let r = mc.r;
    let a_stack = params[&format!("{layer_type}.a")].f32s().unwrap();
    let b_stack = params[&format!("{layer_type}.b")].f32s().unwrap();
    let a = (0..cfg.blocks)
        .map(|k| a_stack[k * r * i..(k + 1) * r * i].to_vec())
        .collect();
    let b = (0..cfg.blocks)
        .map(|k| b_stack[k * o * r..(k + 1) * o * r].to_vec())
        .collect();
    Factors { r, in_dim: i, out_dim: o, a, b }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::init_params;
    use crate::config::presets;

    #[test]
    fn blocks_are_independent_slices() {
        let cfg = presets::tiny();
        let mc = MethodCfg::lora(2);
        let params = init_params(&cfg, &mc, 0);
        let f = materialize(&cfg, &mc, &params, "q");
        assert_eq!(f.a.len(), cfg.blocks);
        // different blocks were initialized independently
        assert_ne!(f.a[0], f.a[1]);
        // b zero-init
        assert!(f.b.iter().all(|b| b.iter().all(|&x| x == 0.0)));
    }
}
