//! Mixture of Shards (paper Sec. 3): global shard pools, the index-based
//! router with all four differentiation strategies, host-side
//! materialization, and the combinatorial-diversity analysis.

pub mod diversity;
pub mod materialize;
pub mod pool;
pub mod router;
