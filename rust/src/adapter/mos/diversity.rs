//! Combinatorial-diversity analysis (paper Appendix B.1).
//!
//! Differentiation is approximated by the number of potential combinations
//! each low-rank matrix pair can take:
//!   pure sharing       C(Le, Le)                      = 1
//!   subset selection   C(Le, r)
//!   pair dissociation  C(Le, r)^2
//!   vector sharding    C(Lle, rl)^2
//! (with privatization reducing the public pool but adding exclusive
//! shards). Counts explode, so everything is computed in log10 space via
//! the log-gamma function.

/// Natural log of Gamma(x) (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// log10 of C(n, k); 0 when k == 0 or k == n; -inf when k > n.
pub fn log10_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    if k == 0 || k == n {
        return 0.0;
    }
    let ln = ln_gamma(n as f64 + 1.0)
        - ln_gamma(k as f64 + 1.0)
        - ln_gamma((n - k) as f64 + 1.0);
    ln / std::f64::consts::LN_10
}

/// log10 of the ordered-selection count P(n, k) = n!/(n-k)! (the router's
/// index vectors are ordered — dissociation enables this, Sec. 3.3).
pub fn log10_perm(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    (ln_gamma(n as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0))
        / std::f64::consts::LN_10
}

/// Diversity (log10 #combinations per low-rank matrix pair) of each scheme,
/// for L blocks, budget rank e, selected rank r, shards-per-vector l.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Diversity {
    pub pure_sharing: f64,
    pub subset_selection: f64,
    pub pair_dissociation: f64,
    pub vector_sharding: f64,
}

pub fn analyze(blocks: u64, e: u64, r: u64, l: u64) -> Diversity {
    let le = blocks * e;
    Diversity {
        pure_sharing: 0.0, // C(Le, Le) = 1
        subset_selection: log10_choose(le, r),
        pair_dissociation: 2.0 * log10_choose(le, r),
        vector_sharding: 2.0 * log10_choose(le * l, r * l),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Gamma(n) = (n-1)!
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - (24f64).ln()).abs() < 1e-9);
        assert!((ln_gamma(11.0) - (3628800f64).ln()).abs() < 1e-8);
        // Gamma(1/2) = sqrt(pi)
        assert!(
            (ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-9
        );
    }

    #[test]
    fn choose_small_cases() {
        assert!((log10_choose(5, 2) - (10f64).log10()).abs() < 1e-9);
        assert!((log10_choose(64, 2) - (2016f64).log10()).abs() < 1e-9);
        assert_eq!(log10_choose(4, 0), 0.0);
        assert_eq!(log10_choose(4, 4), 0.0);
        assert_eq!(log10_choose(3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn perm_exceeds_choose() {
        assert!(log10_perm(10, 3) > log10_choose(10, 3));
        assert!((log10_perm(5, 5) - (120f64).log10()).abs() < 1e-9);
    }

    #[test]
    fn paper_ordering_holds() {
        // Appendix B.1: C(Le,r) < C(Lle, rl) when r < Le and l > 1, and
        // dissociation squares the count.
        let d = analyze(32, 2, 8, 4);
        assert_eq!(d.pure_sharing, 0.0);
        assert!(d.subset_selection > 0.0);
        assert!((d.pair_dissociation - 2.0 * d.subset_selection).abs() < 1e-12);
        assert!(d.vector_sharding > d.pair_dissociation);
    }

    #[test]
    fn sharding_no_gain_when_l1() {
        let d = analyze(32, 2, 8, 1);
        assert!((d.vector_sharding - d.pair_dissociation).abs() < 1e-12);
    }
}
