//! Host-side MoS materialization: gather + concat shards into dense
//! per-block low-rank factors, and the fused routed apply.
//!
//! This is the Rust twin of the L1 pallas kernels (`shard_gather`,
//! `mos_apply_fused`) and the `python/compile/kernels/ref.py` oracle; the
//! integration tests cross-check all three. The coordinator uses it for
//! its precompute pipeline (paper Limitations §C: index routing lets dense
//! matrices be prepared in parallel with preceding blocks).

use super::super::Factors;
use crate::config::{MethodCfg, ModelCfg};
use crate::model::math;
use crate::util::bank::{Bank, Tensor};

/// Gather + concat pool shards into one dense (r, l*s) matrix, row-major.
/// `idx` is the (r*l,) slice of the index matrix for one block.
pub fn gather_rows(pool: &Tensor, idx: &[i32], r: usize, l: usize) -> Vec<f32> {
    let s = pool.shape()[1];
    let data = pool.f32s().expect("pool must be f32");
    let mut out = vec![0.0f32; r * l * s];
    for row in 0..r {
        for j in 0..l {
            let shard = idx[row * l + j] as usize;
            let src = &data[shard * s..(shard + 1) * s];
            let dst_off = row * (l * s) + j * s;
            out[dst_off..dst_off + s].copy_from_slice(src);
        }
    }
    out
}

/// Transpose a row-major (rows, cols) matrix into (cols, rows).
/// (Thin wrapper: the cache-blocked kernel lives in [`math::transpose`].)
pub fn transpose(m: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    math::transpose(m, rows, cols)
}

/// Dense per-block factors for one layer type.
///
/// `params` holds `<t>.pool_a` (n, in/l) and `<t>.pool_b` (n, out/l);
/// `aux` holds `<t>.idx_a`, `<t>.idx_b` (L, r, l) and `<t>.rank_scale`
/// (L, r). The rank scale folds into the A side, matching
/// `python/compile/model.py::materialize`.
pub fn factors(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    params: &Bank,
    aux: &Bank,
    layer_type: &str,
) -> Factors {
    let (o, i) = cfg.dims(layer_type);
    let (r, l) = (mc.r, mc.l);
    let pool_a = &params[&format!("{layer_type}.pool_a")];
    let pool_b = &params[&format!("{layer_type}.pool_b")];
    let idx_a = aux[&format!("{layer_type}.idx_a")].i32s().unwrap();
    let idx_b = aux[&format!("{layer_type}.idx_b")].i32s().unwrap();
    let scale = aux[&format!("{layer_type}.rank_scale")].f32s().unwrap();

    let per = r * l;
    let build_block = |k: usize| -> (Vec<f32>, Vec<f32>) {
        let mut ak = gather_rows(pool_a, &idx_a[k * per..(k + 1) * per], r, l);
        // fold rank scale into A rows
        for row in 0..r {
            let s = scale[k * r + row];
            if s != 1.0 {
                for v in &mut ak[row * i..(row + 1) * i] {
                    *v *= s;
                }
            }
        }
        // B: gather as rows (r, o) then transpose to (o, r)
        let bt = gather_rows(pool_b, &idx_b[k * per..(k + 1) * per], r, l);
        (ak, transpose(&bt, r, o))
    };
    // per-block gathers are independent (index routing = pure precompute,
    // paper Limitations §C) — fan them out on the shared pool when the
    // tenant is big enough for the sync overhead to pay off
    let built: Vec<(Vec<f32>, Vec<f32>)> = if cfg.blocks * r * (i + o) >= 1 << 16 {
        math::pool().scoped_map((0..cfg.blocks).collect(), build_block)
    } else {
        (0..cfg.blocks).map(build_block).collect()
    };
    let mut a = Vec::with_capacity(cfg.blocks);
    let mut b = Vec::with_capacity(cfg.blocks);
    for (ak, bk) in built {
        a.push(ak);
        b.push(bk);
    }
    Factors { r, in_dim: i, out_dim: o, a, b }
}

/// Fused routed low-rank apply for one block:
/// `y[m, o] += scale * (x[m, i] @ A^T) @ B^T` without materializing `ΔW`.
/// The Rust twin of the pallas `mos_apply_fused` kernel.
pub fn apply_fused(
    x: &[f32],
    m: usize,
    factors: &Factors,
    block: usize,
    scale: f32,
    y: &mut [f32],
) {
    let (r, i, o) = (factors.r, factors.in_dim, factors.out_dim);
    debug_assert_eq!(x.len(), m * i);
    debug_assert_eq!(y.len(), m * o);
    // one GEMM engine for everything (model::math):
    // t = x @ A^T : (m, r), then y += scale * t @ B^T (B is (o, r))
    let mut t = math::scratch_take(m * r);
    math::matmul_nt_acc(x, &factors.a[block], &mut t, m, i, r);
    math::gemm(
        m,
        o,
        r,
        scale,
        &t,
        math::Trans::N,
        &factors.b[block],
        math::Trans::T,
        y,
    );
    math::scratch_put(t);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::mos::router::build_router;
    use crate::adapter::init_params;
    use crate::config::presets;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn gather_exact() {
        let pool = Tensor::from_f32(
            &[6, 2],
            (0..12).map(|x| x as f32).collect(),
        );
        let out = gather_rows(&pool, &[0, 5, 3, 3], 2, 2);
        assert_eq!(out, vec![0., 1., 10., 11., 6., 7., 6., 7.]);
    }

    #[test]
    fn transpose_roundtrip() {
        prop::check("transpose-involutive", 20, |rng| {
            let r = rng.range(1, 8);
            let c = rng.range(1, 8);
            let m: Vec<f32> = (0..r * c).map(|_| rng.normal()).collect();
            let back = transpose(&transpose(&m, r, c), c, r);
            prop::assert_allclose(&m, &back, 0.0, 0.0)
        });
    }

    #[test]
    fn factors_shapes_and_scale_folding() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let mut params = init_params(&cfg, &mc, 0);
        // randomize pool_b so B is nonzero
        let mut rng = Rng::new(1, 0);
        for t in crate::config::LAYER_TYPES {
            let key = format!("{t}.pool_b");
            let old = params[&key].clone();
            params.insert(
                key,
                Tensor::from_f32(old.shape(), rng.normal_vec(old.len(), 0.1)),
            );
        }
        let rs = build_router(&cfg, &mc, 0);
        let f = factors(&cfg, &mc, &params, rs.bank(), "gate");
        let (o, i) = cfg.dims("gate");
        assert_eq!(f.a.len(), cfg.blocks);
        assert_eq!(f.a[0].len(), mc.r * i);
        assert_eq!(f.b[0].len(), o * mc.r);
        // doubling rank_scale doubles A, leaves B
        let mut bank2 = rs.bank().clone();
        let key = "gate.rank_scale".to_string();
        let sc = bank2[&key].clone();
        bank2.insert(
            key,
            Tensor::from_f32(
                sc.shape(),
                sc.f32s().unwrap().iter().map(|x| x * 2.0).collect(),
            ),
        );
        let f2 = factors(&cfg, &mc, &params, &bank2, "gate");
        for k in 0..cfg.blocks {
            let want: Vec<f32> = f.a[k].iter().map(|x| x * 2.0).collect();
            prop::assert_allclose(&f2.a[k], &want, 1e-6, 1e-6).unwrap();
            prop::assert_allclose(&f2.b[k], &f.b[k], 0.0, 0.0).unwrap();
        }
    }

    #[test]
    fn fused_apply_matches_dense_delta() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(4, 2, 2, 0);
        prop::check("fused-vs-dense", 10, |rng| {
            let mut params = init_params(&cfg, &mc, rng.next_u64());
            for t in crate::config::LAYER_TYPES {
                let key = format!("{t}.pool_b");
                let old = params[&key].clone();
                params.insert(
                    key,
                    Tensor::from_f32(
                        old.shape(),
                        rng.normal_vec(old.len(), 0.2),
                    ),
                );
            }
            let rs = build_router(&cfg, &mc, rng.next_u64());
            let f = factors(&cfg, &mc, &params, rs.bank(), "q");
            let (o, i) = cfg.dims("q");
            let m = rng.range(1, 4);
            let x = rng.normal_vec(m * i, 1.0);
            let block = rng.range(0, cfg.blocks);
            let mut y = vec![0.0f32; m * o];
            apply_fused(&x, m, &f, block, 0.5, &mut y);
            // dense: y2 = 0.5 * x @ delta^T
            let delta = f.delta(block); // (o, i)
            let mut y2 = vec![0.0f32; m * o];
            for mm in 0..m {
                for oo in 0..o {
                    let mut acc = 0.0;
                    for ii in 0..i {
                        acc += x[mm * i + ii] * delta[oo * i + ii];
                    }
                    y2[mm * o + oo] = 0.5 * acc;
                }
            }
            prop::assert_allclose(&y, &y2, 1e-4, 1e-4)
        });
    }
}
