//! Global shard-pool layout (paper Sec. 3.1, 3.5).
//!
//! Per linear-layer type the pool holds `n = e * L * l` shards — exactly the
//! trainable budget of a rank-`e` LoRA over `L` blocks. Privatization splits
//! the pool into a public prefix and a private tail; the private tail is
//! sized so each block can own `private_rank` rank-slots of `l` shards per
//! side, each private shard used exactly once globally.

use crate::config::{MethodCfg, ModelCfg};

/// Resolved pool geometry for one layer type & side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolLayout {
    /// total shards in the pool
    pub n: usize,
    /// shards in the public segment `[0, n_public)`
    pub n_public: usize,
    /// shard width (in/l for the A side, out/l for the B side)
    pub shard_width: usize,
    /// shards per rank-vector
    pub l: usize,
    /// rank of each materialized low-rank matrix
    pub r: usize,
    /// rank slots per block routed to the private segment
    pub private_rank: usize,
    /// number of blocks sharing this pool
    pub blocks: usize,
}

impl PoolLayout {
    /// Layout for the A side (`dim` = in features) or B side (`dim` = out).
    pub fn new(cfg: &ModelCfg, mc: &MethodCfg, dim: usize) -> PoolLayout {
        assert_eq!(dim % mc.l, 0, "l={} must divide dim={dim}", mc.l);
        let n = mc.pool_shards(cfg.blocks);
        let private = cfg.blocks * mc.private_rank * mc.l;
        assert!(
            private < n,
            "privatization exhausts the pool: {private} private of {n} \
             (need private_rank < e = {})",
            mc.e
        );
        PoolLayout {
            n,
            n_public: n - private,
            shard_width: dim / mc.l,
            l: mc.l,
            r: mc.r,
            private_rank: mc.private_rank,
            blocks: cfg.blocks,
        }
    }

    /// Total f32 parameter count of this pool.
    pub fn param_count(&self) -> usize {
        self.n * self.shard_width
    }

    /// The private shard owned by `(block, private_slot, shard_pos)`.
    /// Deterministic, collision-free, covers the whole private tail.
    pub fn private_shard(&self, block: usize, slot: usize, pos: usize) -> usize {
        debug_assert!(slot < self.private_rank && pos < self.l);
        self.n_public + (block * self.private_rank + slot) * self.l + pos
    }

    /// True if shard index lies in the private tail.
    pub fn is_private(&self, shard: usize) -> bool {
        shard >= self.n_public
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn budget_matches_rank_e_lora() {
        let cfg = presets::tiny();
        for l in [1, 2, 4, 8] {
            let mc = MethodCfg::mos(8, l, 2, 0);
            let (o, i) = cfg.dims("q");
            let a = PoolLayout::new(&cfg, &mc, i);
            let b = PoolLayout::new(&cfg, &mc, o);
            assert_eq!(
                a.param_count() + b.param_count(),
                mc.e * cfg.blocks * (i + o),
                "l={l}"
            );
        }
    }

    #[test]
    fn private_shards_unique_and_cover_tail() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let lay = PoolLayout::new(&cfg, &mc, 64);
        let mut seen = std::collections::HashSet::new();
        for k in 0..lay.blocks {
            for s in 0..lay.private_rank {
                for p in 0..lay.l {
                    let sh = lay.private_shard(k, s, p);
                    assert!(lay.is_private(sh));
                    assert!(sh < lay.n);
                    assert!(seen.insert(sh), "shard {sh} reused");
                }
            }
        }
        assert_eq!(seen.len(), lay.n - lay.n_public);
    }

    #[test]
    #[should_panic(expected = "privatization exhausts")]
    fn rejects_private_rank_ge_e() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 2); // private_rank == e
        PoolLayout::new(&cfg, &mc, 64);
    }

    #[test]
    fn no_privatization_means_all_public() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 0);
        let lay = PoolLayout::new(&cfg, &mc, 64);
        assert_eq!(lay.n_public, lay.n);
    }
}
