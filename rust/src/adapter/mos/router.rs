//! The MoE-like index router (paper Sec. 3.2-3.5 and Limitations §C).
//!
//! The router is *index-based, not activation-based*: all routing decisions
//! are frozen at adapter-creation time into index matrices `I_a, I_b ∈
//! N^{L×r×l}` plus per-rank scales. This is what lets the coordinator
//! precompute dense low-rank matrices in parallel with preceding blocks and
//! reuse every existing LoRA serving technique.
//!
//! Differentiation strategies and how they map to index-space:
//! * **subset selection** — each block samples its own (ordered) subset of
//!   pool shards instead of taking the whole pool in order;
//! * **pair dissociation** — `I_b` sampled independently of `I_a`
//!   (ablation `-pd`: `I_b == I_a`);
//! * **vector sharding** — `l > 1` shards concatenated per rank-vector
//!   (ablation `-vs`: `l == 1`);
//! * **shard privatization** — the last `private_rank` rank-slots of every
//!   block route to block-owned shards in the private pool tail, each used
//!   exactly once globally (ablation `-sp`: `private_rank == 0`).

use super::pool::PoolLayout;
use crate::config::{MethodCfg, ModelCfg, LAYER_TYPES};
use crate::util::bank::{Bank, Tensor};
use crate::util::rng::Rng;

/// Frozen router state for every layer type, stored as a [`Bank`] whose
/// tensor names match the AOT artifact aux-input specs
/// (`<type>.idx_a`, `<type>.idx_b`, `<type>.rank_scale`).
#[derive(Debug, Clone)]
pub struct RouterState {
    bank: Bank,
    pub seed: u64,
}

impl RouterState {
    pub fn into_bank(self) -> Bank {
        self.bank
    }

    pub fn bank(&self) -> &Bank {
        &self.bank
    }

    /// (L, r, l) indices for one layer type & side ("idx_a"/"idx_b").
    pub fn indices(&self, layer_type: &str, side: &str) -> &Tensor {
        &self.bank[&format!("{layer_type}.{side}")]
    }

    pub fn rank_scale(&self, layer_type: &str) -> &Tensor {
        &self.bank[&format!("{layer_type}.rank_scale")]
    }
}

/// Build the frozen router for a MoS adapter. Deterministic in
/// `(cfg, mc, seed)`; distinct tenants use distinct seeds.
pub fn build_router(cfg: &ModelCfg, mc: &MethodCfg, seed: u64) -> RouterState {
    let mut bank = Bank::new();
    let mut rng = Rng::new(seed, 23);
    for (ti, t) in LAYER_TYPES.iter().enumerate() {
        let (o, i) = cfg.dims(t);
        let lay_a = PoolLayout::new(cfg, mc, i);
        let lay_b = PoolLayout::new(cfg, mc, o);
        let mut lrng = rng.fork(ti as u64 + 1);

        let idx_a = sample_side(&lay_a, mc, &mut lrng);
        let idx_b = if mc.pair_dissociation {
            sample_side(&lay_b, mc, &mut lrng)
        } else {
            idx_a.clone() // -pd ablation / paper Sec. 2 schemes
        };
        let scale = sample_scale(cfg.blocks, mc, &mut lrng);

        let shape = [cfg.blocks, mc.r, mc.l];
        bank.insert(format!("{t}.idx_a"), Tensor::from_i32(&shape, idx_a));
        bank.insert(format!("{t}.idx_b"), Tensor::from_i32(&shape, idx_b));
        bank.insert(
            format!("{t}.rank_scale"),
            Tensor::from_f32(&[cfg.blocks, mc.r], scale),
        );
    }
    RouterState { bank, seed }
}

/// Index matrix (L*r*l, flattened) for one side of one layer type.
fn sample_side(lay: &PoolLayout, mc: &MethodCfg, rng: &mut Rng) -> Vec<i32> {
    let (blocks, r, l) = (lay.blocks, lay.r, lay.l);
    let mut out = vec![0i32; blocks * r * l];
    for k in 0..blocks {
        let public_slots = r - lay.private_rank;
        if mc.subset_selection {
            // Ordered subset: sample r*l shard picks from the public
            // segment, all-distinct when the pool is large enough (the
            // C(n, k) regime of Appendix B.1), iid otherwise.
            let need = public_slots * l;
            let picks: Vec<usize> = if need <= lay.n_public {
                rng.sample_distinct(lay.n_public, need)
            } else {
                (0..need).map(|_| rng.range(0, lay.n_public)).collect()
            };
            for slot in 0..public_slots {
                for j in 0..l {
                    out[(k * r + slot) * l + j] = picks[slot * l + j] as i32;
                }
            }
        } else {
            // Pure sharing: every block takes the pool in order. r == e*L
            // and l == 1 in the paper's scheme; generalized to any r by
            // cycling.
            for slot in 0..public_slots {
                for j in 0..l {
                    out[(k * r + slot) * l + j] =
                        ((slot * l + j) % lay.n_public) as i32;
                }
            }
        }
        // Private tail: block-owned shards, each used exactly once.
        for slot in 0..lay.private_rank {
            for j in 0..l {
                out[(k * r + public_slots + slot) * l + j] =
                    lay.private_shard(k, slot, j) as i32;
            }
        }
    }
    out
}

/// Per-(block, rank) scale vector: ones normally, frozen N(0,1) draws for
/// the "random scaling" scheme of Sec. 2.
fn sample_scale(blocks: usize, mc: &MethodCfg, rng: &mut Rng) -> Vec<f32> {
    let n = blocks * mc.r;
    if mc.random_scaling {
        (0..n).map(|_| rng.normal()).collect()
    } else {
        vec![1.0; n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::prop;
    use std::collections::HashSet;

    fn tiny() -> ModelCfg {
        presets::tiny()
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let r1 = build_router(&cfg, &mc, 42);
        let r2 = build_router(&cfg, &mc, 42);
        assert_eq!(r1.bank(), r2.bank());
        let r3 = build_router(&cfg, &mc, 43);
        assert_ne!(r1.bank(), r3.bank());
    }

    #[test]
    fn indices_in_pool_bounds() {
        let cfg = tiny();
        prop::check("router-bounds", 30, |rng| {
            let l = *rng.choice(&[1usize, 2, 4]);
            let e = *rng.choice(&[2usize, 4]);
            let p = rng.range(0, e); // private_rank < e
            let r = rng.range(p.max(1), 3 * e);
            let mc = MethodCfg::mos(r, l, e, p);
            let rs = build_router(&cfg, &mc, rng.next_u64());
            for t in LAYER_TYPES {
                for side in ["idx_a", "idx_b"] {
                    let dim = if side == "idx_a" {
                        cfg.dims(t).1
                    } else {
                        cfg.dims(t).0
                    };
                    let lay = PoolLayout::new(&cfg, &mc, dim);
                    let idx = rs.indices(t, side).i32s().unwrap();
                    if idx.iter().any(|&x| x < 0 || x as usize >= lay.n) {
                        return Err(format!("{t}.{side} out of bounds"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn private_shards_used_exactly_once() {
        let cfg = tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let rs = build_router(&cfg, &mc, 7);
        for t in LAYER_TYPES {
            for (side, dim) in [("idx_a", cfg.dims(t).1), ("idx_b", cfg.dims(t).0)] {
                let lay = PoolLayout::new(&cfg, &mc, dim);
                let idx = rs.indices(t, side).i32s().unwrap();
                let mut seen = HashSet::new();
                for &x in idx {
                    if lay.is_private(x as usize) {
                        assert!(
                            seen.insert(x),
                            "{t}.{side}: private shard {x} reused"
                        );
                    }
                }
                // every block contributed private_rank * l private shards
                assert_eq!(
                    seen.len(),
                    cfg.blocks * mc.private_rank * mc.l,
                    "{t}.{side}"
                );
            }
        }
    }

    #[test]
    fn dissociation_controls_idx_b() {
        let cfg = tiny();
        let mut mc = MethodCfg::mos(8, 2, 2, 0);
        let rs = build_router(&cfg, &mc, 3);
        assert_ne!(
            rs.indices("q", "idx_a").i32s().unwrap(),
            rs.indices("q", "idx_b").i32s().unwrap(),
            "dissociated indices should differ"
        );
        mc.pair_dissociation = false;
        let rs = build_router(&cfg, &mc, 3);
        assert_eq!(
            rs.indices("q", "idx_a").i32s().unwrap(),
            rs.indices("q", "idx_b").i32s().unwrap()
        );
    }

    #[test]
    fn pure_sharing_identical_across_blocks() {
        let cfg = tiny();
        let mc = MethodCfg::pure_sharing(2, cfg.blocks);
        let rs = build_router(&cfg, &mc, 0);
        let idx = rs.indices("q", "idx_a").i32s().unwrap();
        let per = mc.r * mc.l;
        for k in 1..cfg.blocks {
            assert_eq!(idx[..per], idx[k * per..(k + 1) * per]);
        }
        // identity order: shard i at slot i
        for (i, &x) in idx[..per].iter().enumerate() {
            assert_eq!(x as usize, i % mc.pool_shards(cfg.blocks));
        }
        let s = rs.rank_scale("q").f32s().unwrap();
        assert!(s.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn subset_selection_differentiates_blocks() {
        let cfg = tiny();
        // r=4 of pool 8, l=1, no privatization, tied pairs: the Sec. 2
        // "+ Subset Selection" scheme
        let mc = MethodCfg {
            pair_dissociation: false,
            ..MethodCfg::mos(4, 1, 2, 0)
        };
        let rs = build_router(&cfg, &mc, 1);
        let idx = rs.indices("q", "idx_a").i32s().unwrap();
        let per = mc.r * mc.l;
        let mut distinct_blocks = HashSet::new();
        for k in 0..cfg.blocks {
            distinct_blocks.insert(idx[k * per..(k + 1) * per].to_vec());
            // within a block: distinct shards (subset semantics)
            let set: HashSet<i32> =
                idx[k * per..(k + 1) * per].iter().copied().collect();
            assert_eq!(set.len(), per, "block {k} has duplicate shards");
        }
        assert!(distinct_blocks.len() > 1, "all blocks chose the same subset");
    }

    #[test]
    fn random_scaling_draws_normals() {
        let cfg = tiny();
        let mc = MethodCfg {
            random_scaling: true,
            subset_selection: false,
            pair_dissociation: false,
            ..MethodCfg::pure_sharing(2, cfg.blocks)
        };
        let rs = build_router(&cfg, &mc, 5);
        let s = rs.rank_scale("q").f32s().unwrap();
        assert!(s.iter().any(|&x| x != 1.0));
        assert!(s.iter().any(|&x| x < 0.0), "normals should be signed");
    }

    #[test]
    fn layer_types_routed_independently() {
        let cfg = tiny();
        let mc = MethodCfg::mos(8, 2, 2, 0);
        let rs = build_router(&cfg, &mc, 9);
        assert_ne!(
            rs.indices("q", "idx_a").i32s().unwrap(),
            rs.indices("k", "idx_a").i32s().unwrap()
        );
    }
}
