//! PRoLoRA baseline (Wang et al., 2024b): intra-layer sharing by chunk
//! replication with partial rotation. The trainable chunk a0 (L,r,in/m) is
//! tiled m times along the feature axis, chunk j rotated by j along the
//! rank axis (rotation restores the effective rank that plain replication
//! would collapse). Mirrors `python/compile/model.py::_prolora_replicate_*`.

use super::Factors;
use crate::config::{MethodCfg, ModelCfg};
use crate::util::bank::Bank;

pub fn materialize(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    params: &Bank,
    layer_type: &str,
) -> Factors {
    let (o, i) = cfg.dims(layer_type);
    let (r, m) = (mc.r, mc.m);
    let (ic, oc) = (i / m, o / m);
    let a0 = params[&format!("{layer_type}.a0")].f32s().unwrap();
    let b0 = params[&format!("{layer_type}.b0")].f32s().unwrap();
    let mut a = Vec::with_capacity(cfg.blocks);
    let mut b = Vec::with_capacity(cfg.blocks);
    for k in 0..cfg.blocks {
        let a0k = &a0[k * r * ic..(k + 1) * r * ic]; // (r, ic)
        let mut ak = vec![0.0f32; r * i];
        for j in 0..m {
            for rr in 0..r {
                // chunk j takes rows rotated by +j (jnp.roll semantics:
                // out[rr] = in[(rr - j) mod r])
                let src = ((rr + r - (j % r)) % r) * ic;
                let dst = rr * i + j * ic;
                ak[dst..dst + ic].copy_from_slice(&a0k[src..src + ic]);
            }
        }
        let b0k = &b0[k * oc * r..(k + 1) * oc * r]; // (oc, r)
        let mut bk = vec![0.0f32; o * r];
        for j in 0..m {
            for row in 0..oc {
                for rr in 0..r {
                    // roll along rank axis: out[row, rr] = in[row, (rr-j) mod r]
                    let src = row * r + ((rr + r - (j % r)) % r);
                    bk[(j * oc + row) * r + rr] = b0k[src];
                }
            }
        }
        a.push(ak);
        b.push(bk);
    }
    Factors { r, in_dim: i, out_dim: o, a, b }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::init_params;
    use crate::config::presets;

    #[test]
    fn replication_with_rotation() {
        let cfg = presets::tiny();
        let mc = MethodCfg::prolora(4, 2);
        let params = init_params(&cfg, &mc, 0);
        let f = materialize(&cfg, &mc, &params, "q");
        let i = cfg.dims("q").1;
        let (r, ic) = (4, i / 2);
        let ak = &f.a[0];
        // chunk 1 row rr == chunk 0 row (rr-1) mod r
        for rr in 0..r {
            let prev = (rr + r - 1) % r;
            assert_eq!(
                &ak[rr * i + ic..rr * i + 2 * ic],
                &ak[prev * i..prev * i + ic],
                "row {rr}"
            );
        }
    }

    #[test]
    fn param_budget_is_lora_over_m() {
        let cfg = presets::tiny();
        let mc = MethodCfg::prolora(8, 4);
        let params = init_params(&cfg, &mc, 0);
        let total: usize = params.values().map(|t| t.len()).sum();
        let lora8: usize = {
            let p = init_params(&cfg, &MethodCfg::lora(8), 0);
            p.values().map(|t| t.len()).sum()
        };
        assert_eq!(total, lora8 / 4);
    }
}
