//! Checkpointing: adapter params + router state + metadata. The bank format
//! is the same binary container the artifacts use; metadata is JSON.

use crate::config::{Method, MethodCfg};
use crate::util::bank::{read_bank, write_bank, Bank};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// A saved adapter: everything needed to serve a tenant.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub preset: String,
    pub mc: MethodCfg,
    pub router_seed: u64,
    pub params: Bank,
    pub aux: Bank,
}

impl Checkpoint {
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("mkdir {}", dir.display()))?;
        write_bank(&dir.join("params.bin"), &self.params)?;
        write_bank(&dir.join("aux.bin"), &self.aux)?;
        let meta = Json::obj(vec![
            ("preset", Json::str(&self.preset)),
            ("method", Json::str(self.mc.method.as_str())),
            ("r", Json::num(self.mc.r as f64)),
            ("l", Json::num(self.mc.l as f64)),
            ("e", Json::num(self.mc.e as f64)),
            ("m", Json::num(self.mc.m as f64)),
            ("alpha", Json::num(self.mc.alpha)),
            ("private_rank", Json::num(self.mc.private_rank as f64)),
            ("pair_dissociation", Json::Bool(self.mc.pair_dissociation)),
            ("subset_selection", Json::Bool(self.mc.subset_selection)),
            ("random_scaling", Json::Bool(self.mc.random_scaling)),
            ("router_seed", Json::num(self.router_seed as f64)),
        ]);
        std::fs::write(dir.join("meta.json"), meta.to_string_pretty())?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Checkpoint> {
        let meta = Json::parse(
            &std::fs::read_to_string(dir.join("meta.json"))
                .with_context(|| format!("reading {}/meta.json", dir.display()))?,
        )?;
        let method = Method::parse(meta.req_str("method")?)?;
        let mc = MethodCfg {
            method,
            r: meta.req_usize("r")?,
            l: meta.req_usize("l")?,
            e: meta.req_usize("e")?,
            m: meta.req_usize("m")?,
            alpha: meta.req_f64("alpha")?,
            private_rank: meta.req_usize("private_rank")?,
            pair_dissociation: meta
                .get("pair_dissociation")
                .and_then(|j| j.as_bool())
                .unwrap_or(true),
            subset_selection: meta
                .get("subset_selection")
                .and_then(|j| j.as_bool())
                .unwrap_or(true),
            random_scaling: meta
                .get("random_scaling")
                .and_then(|j| j.as_bool())
                .unwrap_or(false),
        };
        Ok(Checkpoint {
            preset: meta.req_str("preset")?.to_string(),
            mc,
            router_seed: meta.req_usize("router_seed")? as u64,
            params: read_bank(&dir.join("params.bin"))?,
            aux: read_bank(&dir.join("aux.bin"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter;
    use crate::config::presets;

    #[test]
    fn roundtrip() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let params = adapter::init_params(&cfg, &mc, 3);
        let aux = adapter::mos::router::build_router(&cfg, &mc, 9).into_bank();
        let ck = Checkpoint {
            preset: "tiny".into(),
            mc: mc.clone(),
            router_seed: 9,
            params,
            aux,
        };
        let dir = std::env::temp_dir().join("mos_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.mc, mc);
        assert_eq!(back.preset, "tiny");
        assert_eq!(back.router_seed, 9);
        assert_eq!(back.params, ck.params);
        assert_eq!(back.aux, ck.aux);
    }

    #[test]
    fn load_missing_errors() {
        let dir = std::env::temp_dir().join("mos_ckpt_none");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Checkpoint::load(&dir).is_err());
    }
}
