//! PJRT training backend: drives the AOT train-step artifact. State
//! (params, optimizer moments) round-trips as named tensors; the hot-path
//! buffer-resident variant is used by the perf pass.

use super::Backend;
use crate::config::{Method, MethodCfg, ModelCfg};
use crate::data::loader::Batch;
use crate::runtime::{Executable, Manifest, Runtime};
use crate::util::bank::{read_bank, Bank, Tensor};
use anyhow::{Context, Result};

pub struct PjrtBackend {
    pub cfg: ModelCfg,
    pub mc: MethodCfg,
    train_exe: Executable,
    fwd_exe: Executable,
    /// frozen base + frozen aux from the artifact bank
    pub bank: Bank,
    /// trainable params (updated in place each step)
    pub params: Bank,
    pub opt_m: Bank,
    pub opt_v: Bank,
    /// router state / frozen matrices (runtime inputs)
    pub aux: Bank,
    step: u64,
}

impl PjrtBackend {
    /// Load everything for (preset, method tag). The router seed controls
    /// MoS index sampling — the Rust-owned routing decision.
    pub fn load(
        rt: &Runtime,
        manifest: &Manifest,
        preset: &str,
        mc: &MethodCfg,
        router_seed: u64,
    ) -> Result<PjrtBackend> {
        let tag = mc.tag();
        let cfg = manifest
            .presets
            .get(preset)
            .with_context(|| format!("preset '{preset}'"))?
            .clone();
        mc.validate(&cfg)?;
        let train_exe = rt.load(manifest, &format!("train_{tag}_{preset}"))?;
        let fwd_exe = rt.load(manifest, &format!("fwd_{tag}_{preset}"))?;
        let bank = read_bank(&manifest.bank_path(preset))?;
        let params = read_bank(&manifest.init_path(preset, &tag))?;
        let zeros: Bank = params
            .iter()
            .map(|(k, t)| (k.clone(), Tensor::zeros(t.shape())))
            .collect();
        let aux = build_aux(&cfg, mc, &bank, router_seed);
        Ok(PjrtBackend {
            cfg,
            mc: mc.clone(),
            train_exe,
            fwd_exe,
            bank,
            params,
            opt_m: zeros.clone(),
            opt_v: zeros,
            aux,
            step: 0,
        })
    }

    fn assemble_train_inputs(&self, batch: &Batch, lr: f32) -> Bank {
        let mut inp = Bank::new();
        for spec in &self.train_exe.art.inputs {
            let t = match spec.role.as_str() {
                "base" => self.bank[&spec.name].clone(),
                "param" => self.params[&spec.name].clone(),
                "opt_m" => self.opt_m[&spec.name["m.".len()..]].clone(),
                "opt_v" => self.opt_v[&spec.name["v.".len()..]].clone(),
                "scalar" => match spec.name.as_str() {
                    "step" => Tensor::from_f32(&[1], vec![(self.step + 1) as f32]),
                    "lr" => Tensor::from_f32(&[1], vec![lr]),
                    s => panic!("unknown scalar {s}"),
                },
                "data" => match spec.name.as_str() {
                    "tokens" => Tensor::from_i32(&spec.shape, batch.tokens.clone()),
                    "targets" => Tensor::from_i32(&spec.shape, batch.targets.clone()),
                    "weight" => Tensor::from_f32(&spec.shape, batch.weight.clone()),
                    s => panic!("unknown data input {s}"),
                },
                "aux" => self
                    .aux
                    .get(&spec.name)
                    .or_else(|| self.bank.get(&spec.name))
                    .unwrap_or_else(|| panic!("missing aux '{}'", spec.name))
                    .clone(),
                r => panic!("unknown role {r}"),
            };
            inp.insert(spec.name.clone(), t);
        }
        inp
    }
}

/// Build runtime aux inputs for a method: MoS router state (indices +
/// scales) from the Rust router; VeRA frozen matrices come from the bank.
pub fn build_aux(cfg: &ModelCfg, mc: &MethodCfg, _bank: &Bank, seed: u64) -> Bank {
    match mc.method {
        Method::MoS => {
            crate::adapter::mos::router::build_router(cfg, mc, seed).into_bank()
        }
        _ => Bank::new(), // vera frozen matrices live in the artifact bank
    }
}

impl Backend for PjrtBackend {
    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        let inputs = self.assemble_train_inputs(batch, lr);
        let out = self.train_exe.execute_bank(&inputs)?;
        let mut loss = 0.0f32;
        for (name, t) in out {
            if name == "loss" {
                loss = t.f32s().unwrap()[0];
            } else if let Some(p) = name.strip_prefix("m.") {
                self.opt_m.insert(p.to_string(), t);
            } else if let Some(p) = name.strip_prefix("v.") {
                self.opt_v.insert(p.to_string(), t);
            } else {
                self.params.insert(name, t);
            }
        }
        self.step += 1;
        Ok(loss)
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut inp = Bank::new();
        for spec in &self.fwd_exe.art.inputs {
            let t = match spec.role.as_str() {
                "base" => self.bank[&spec.name].clone(),
                "param" => self.params[&spec.name].clone(),
                "aux" => self
                    .aux
                    .get(&spec.name)
                    .or_else(|| self.bank.get(&spec.name))
                    .unwrap_or_else(|| panic!("missing aux '{}'", spec.name))
                    .clone(),
                "data" => Tensor::from_i32(&spec.shape, tokens.to_vec()),
                r => panic!("unexpected role {r} in fwd"),
            };
            inp.insert(spec.name.clone(), t);
        }
        let out = self.fwd_exe.execute_bank(&inp)?;
        Ok(out["logits"].f32s().unwrap().to_vec())
    }

    fn params(&self) -> &Bank {
        &self.params
    }

    fn shape(&self) -> (usize, usize, usize) {
        (self.cfg.batch, self.cfg.seq, self.cfg.vocab)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
