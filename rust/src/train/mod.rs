//! Training orchestrator: drives the AOT train-step artifact (PJRT backend)
//! or the host model (host backend) over the synthetic task loaders, with
//! the paper's linear-warmup schedule, logging, eval, and checkpointing.

pub mod checkpoint;
pub mod host;
pub mod pjrt;

use crate::data::loader::{Batch, Loader};
use crate::data::tasks::Task;
use crate::eval::{evaluate, EvalReport};
use crate::model::adamw::lr_schedule;
use crate::util::bank::Bank;
use anyhow::Result;

/// A training/inference backend. The coordinator and benches are generic
/// over this, so every experiment can run on the host oracle or on the
/// PJRT artifacts interchangeably.
pub trait Backend {
    /// One optimizer step; returns the loss.
    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32>;
    /// Forward: padded tokens (batch*seq) -> logits (batch*seq*vocab).
    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;
    /// Current trainable parameters.
    fn params(&self) -> &Bank;
    /// Geometry.
    fn shape(&self) -> (usize, usize, usize); // (batch, seq, vocab)
    fn name(&self) -> &'static str;
}

/// Result of a full train-then-eval run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub losses: Vec<f32>,
    pub report: EvalReport,
    pub train_seconds: f64,
}

/// Train `backend` on `task` for `steps`, then evaluate `eval_n` examples.
pub fn run(
    backend: &mut dyn Backend,
    task_ctor: impl Fn() -> Task,
    steps: usize,
    peak_lr: f64,
    eval_n: usize,
    log_every: usize,
) -> Result<RunResult> {
    let (batch, seq, vocab) = backend.shape();
    let mut loader = Loader::new(task_ctor(), batch, seq);
    let mut losses = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let lr = lr_schedule(step, steps, peak_lr, 0.03) as f32;
        let b = loader.next_train();
        let loss = backend.train_step(&b, lr)?;
        losses.push(loss);
        if log_every > 0 && (step % log_every == 0 || step + 1 == steps) {
            crate::info!(
                "step {:>4}/{} loss {:.4} lr {:.2e} [{}]",
                step + 1,
                steps,
                loss,
                lr,
                backend.name()
            );
        }
    }
    let train_seconds = t0.elapsed().as_secs_f64();
    let task = task_ctor();
    let mut fwd = |tokens: &[i32]| backend.forward(tokens).expect("forward");
    let report = evaluate(&task, &mut fwd, eval_n, batch, seq, vocab);
    Ok(RunResult { losses, report, train_seconds })
}

/// Smoothed final loss (mean of last k) — the bench tables' loss column.
pub fn final_loss(losses: &[f32], k: usize) -> f64 {
    let k = k.min(losses.len()).max(1);
    let tail = &losses[losses.len() - k..];
    tail.iter().map(|&x| x as f64).sum::<f64>() / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_loss_tail_mean() {
        let l = vec![5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(final_loss(&l, 2), 1.5);
        assert_eq!(final_loss(&l, 100), 3.0);
        assert_eq!(final_loss(&l, 0), 1.0);
    }
}
