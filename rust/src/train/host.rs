//! Host training backend: the pure-Rust model + AdamW. Used by the table
//! benches (no per-config XLA compile) and as the numerics oracle.

use super::Backend;
use crate::config::{MethodCfg, ModelCfg};
use crate::data::loader::Batch;
use crate::model::adamw::AdamW;
use crate::model::HostModel;
use crate::util::bank::Bank;
use anyhow::Result;

pub struct HostBackend {
    pub model: HostModel,
    opt: AdamW,
}

impl HostBackend {
    pub fn new(cfg: &ModelCfg, mc: &MethodCfg, seed: u64) -> HostBackend {
        let model = HostModel::init(cfg, mc, seed);
        let opt = AdamW::new(&model.params);
        HostBackend { model, opt }
    }

    pub fn from_model(model: HostModel) -> HostBackend {
        let opt = AdamW::new(&model.params);
        HostBackend { model, opt }
    }

    /// Init with an explicit (e.g. pretrained, artifact-bank) base.
    pub fn with_base(
        cfg: &ModelCfg,
        mc: &MethodCfg,
        seed: u64,
        base: Bank,
    ) -> HostBackend {
        let mut model = HostModel::init(cfg, mc, seed);
        model.base = base;
        HostBackend::from_model(model)
    }
}

impl Backend for HostBackend {
    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        let (loss, grads) = self.model.loss_and_grads(
            &batch.tokens,
            &batch.targets,
            &batch.weight,
        );
        self.opt.update(&mut self.model.params, &grads, lr);
        self.model.invalidate_factors();
        Ok(loss)
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        Ok(self.model.forward(tokens))
    }

    fn params(&self) -> &Bank {
        &self.model.params
    }

    fn shape(&self) -> (usize, usize, usize) {
        (self.model.cfg.batch, self.model.cfg.seq, self.model.cfg.vocab)
    }

    fn name(&self) -> &'static str {
        "host"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::data::tasks::{Task, TaskKind};
    use crate::train::{final_loss, run};

    fn fast_tiny() -> ModelCfg {
        // tiny preset with a smaller batch for quick unit tests
        let mut c = presets::tiny();
        c.batch = 4;
        c
    }

    #[test]
    fn host_training_reduces_loss_lora() {
        let cfg = fast_tiny();
        let mut be = HostBackend::new(&cfg, &MethodCfg::lora(2), 0);
        let r = run(
            &mut be,
            || Task::new(TaskKind::Recall, 0),
            30,
            5e-3,
            0,
            0,
        )
        .unwrap();
        let first = final_loss(&r.losses[..5], 5);
        let last = final_loss(&r.losses, 5);
        assert!(
            last < first - 0.2,
            "loss did not drop: {first:.3} -> {last:.3}"
        );
    }

    #[test]
    fn host_training_reduces_loss_mos() {
        let cfg = fast_tiny();
        let mut be = HostBackend::new(&cfg, &MethodCfg::mos(8, 2, 2, 1), 0);
        let r = run(
            &mut be,
            || Task::new(TaskKind::Recall, 0),
            30,
            5e-3,
            0,
            0,
        )
        .unwrap();
        let first = final_loss(&r.losses[..5], 5);
        let last = final_loss(&r.losses, 5);
        assert!(
            last < first - 0.2,
            "loss did not drop: {first:.3} -> {last:.3}"
        );
    }
}
