//! HTTP/1.1 wire handling for the front door: request parsing with hard
//! size/time limits, plain and chunked response writing.
//!
//! Deliberately minimal — the edge speaks exactly the subset the routes
//! in [`super`] need: one request per connection (`Connection: close`),
//! `Content-Length` bodies in, fixed or chunked bodies out. No keep-alive,
//! no pipelining, no transfer-encoding on the request side.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::TcpStream;

/// Cap on the request line + headers. A client that cannot name a route
/// and a content length in 8 KiB is not one of ours.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;
/// Cap on the request body (prompts and tenant specs are small).
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// A parsed request: method, path, and the raw body bytes.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

/// Why a request could not be read. Each variant maps to one status code
/// in [`read_error_status`]; `Closed` means the peer went away before
/// sending a full head and deserves no response at all.
#[derive(Debug)]
pub enum ReadError {
    /// Connection closed (or reset) before a full request arrived.
    Closed,
    /// A read blocked past the socket's configured timeout.
    TimedOut,
    /// Head exceeded [`MAX_HEAD_BYTES`] or body [`MAX_BODY_BYTES`].
    TooLarge,
    /// Not parseable as HTTP/1.1.
    Malformed(&'static str),
}

/// Status code + reason for a request that never parsed.
pub fn read_error_status(e: &ReadError) -> Option<(u16, &'static str)> {
    match e {
        ReadError::Closed => None,
        ReadError::TimedOut => Some((408, "request head/body timed out")),
        ReadError::TooLarge => Some((413, "request exceeds size limits")),
        ReadError::Malformed(why) => Some((400, why)),
    }
}

fn io_read_error(e: io::Error) -> ReadError {
    match e.kind() {
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
            ReadError::TimedOut
        }
        _ => ReadError::Closed,
    }
}

/// Read one HTTP/1.1 request off `stream`. The caller is expected to have
/// set a read timeout on the socket — that plus the byte caps bound both
/// dimensions (time and size) a hostile client could stretch.
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest, ReadError> {
    // head: byte-at-a-time until CRLFCRLF, capped. One syscall per byte
    // would be slow for bulk data, but heads are tiny and this keeps us
    // from reading past the head into the body.
    let mut head = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        if head.len() >= MAX_HEAD_BYTES {
            return Err(ReadError::TooLarge);
        }
        match stream.read(&mut byte) {
            Ok(0) => return Err(ReadError::Closed),
            Ok(_) => head.push(byte[0]),
            Err(e) => return Err(io_read_error(e)),
        }
    }
    let head = std::str::from_utf8(&head)
        .map_err(|_| ReadError::Malformed("head is not utf-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or(ReadError::Malformed("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or(ReadError::Malformed("request line missing path"))?
        .to_string();
    match parts.next() {
        Some(v) if v.starts_with("HTTP/1.") => {}
        _ => return Err(ReadError::Malformed("not HTTP/1.x")),
    }
    let mut headers = HashMap::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(ReadError::Malformed("header line without ':'"));
        };
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }
    let len = match headers.get("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| ReadError::Malformed("bad content-length"))?,
    };
    if len > MAX_BODY_BYTES {
        return Err(ReadError::TooLarge);
    }
    let mut body = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match stream.read(&mut body[got..]) {
            Ok(0) => return Err(ReadError::Closed),
            Ok(n) => got += n,
            Err(e) => return Err(io_read_error(e)),
        }
    }
    Ok(HttpRequest { method, path, headers, body })
}

pub fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response. One request per connection, so
/// every response carries `Connection: close`.
pub fn write_response(
    stream: &mut TcpStream,
    code: u16,
    content_type: &str,
    body: &[u8],
) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        code,
        status_reason(code),
        content_type,
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Begin a chunked (streaming) response.
pub fn start_chunked(
    stream: &mut TcpStream,
    code: u16,
    content_type: &str,
) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        code,
        status_reason(code),
        content_type,
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()
}

/// Write one chunk and flush it — each streamed token must hit the wire
/// immediately, not sit in a buffer until the generation finishes.
pub fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    stream.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

/// Terminate a chunked response.
pub fn end_chunked(stream: &mut TcpStream) -> io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

// ---------------------------------------------------------------------
// Client side: the load harness's HTTP client and the loopback tests
// read responses with the same byte-level care the server reads requests.
// ---------------------------------------------------------------------

/// Read bytes until CRLFCRLF, capped at [`MAX_HEAD_BYTES`].
fn read_head_bytes(stream: &mut TcpStream) -> Result<Vec<u8>, ReadError> {
    let mut head = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        if head.len() >= MAX_HEAD_BYTES {
            return Err(ReadError::TooLarge);
        }
        match stream.read(&mut byte) {
            Ok(0) => return Err(ReadError::Closed),
            Ok(_) => head.push(byte[0]),
            Err(e) => return Err(io_read_error(e)),
        }
    }
    Ok(head)
}

/// Client-side: read a response's status line + headers, leaving the
/// stream positioned at the body.
pub fn read_response_head(
    stream: &mut TcpStream,
) -> Result<(u16, HashMap<String, String>), ReadError> {
    let head = read_head_bytes(stream)?;
    let head = std::str::from_utf8(&head)
        .map_err(|_| ReadError::Malformed("head is not utf-8"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.split(' ');
    match parts.next() {
        Some(v) if v.starts_with("HTTP/1.") => {}
        _ => return Err(ReadError::Malformed("not an HTTP/1.x response")),
    }
    let status = parts
        .next()
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or(ReadError::Malformed("bad status code"))?;
    let mut headers = HashMap::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(ReadError::Malformed("header line without ':'"));
        };
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }
    Ok((status, headers))
}

/// Client-side: read one chunk of a chunked body. `Ok(None)` is the
/// terminal zero-length chunk. Chunk boundaries mirror the server's
/// `write_chunk` calls exactly (one streamed line per chunk), regardless
/// of how TCP segments the bytes.
pub fn read_chunk(
    stream: &mut TcpStream,
) -> Result<Option<Vec<u8>>, ReadError> {
    // size line: hex digits then CRLF
    let mut line = Vec::with_capacity(8);
    let mut byte = [0u8; 1];
    while !line.ends_with(b"\r\n") {
        if line.len() > 18 {
            return Err(ReadError::Malformed("chunk size line too long"));
        }
        match stream.read(&mut byte) {
            Ok(0) => return Err(ReadError::Closed),
            Ok(_) => line.push(byte[0]),
            Err(e) => return Err(io_read_error(e)),
        }
    }
    let size_str = std::str::from_utf8(&line[..line.len() - 2])
        .map_err(|_| ReadError::Malformed("chunk size not utf-8"))?;
    let size = usize::from_str_radix(size_str.trim(), 16)
        .map_err(|_| ReadError::Malformed("chunk size not hex"))?;
    if size > MAX_BODY_BYTES {
        return Err(ReadError::TooLarge);
    }
    let mut data = vec![0u8; size + 2]; // payload + trailing CRLF
    let mut got = 0;
    while got < data.len() {
        match stream.read(&mut data[got..]) {
            Ok(0) => return Err(ReadError::Closed),
            Ok(n) => got += n,
            Err(e) => return Err(io_read_error(e)),
        }
    }
    data.truncate(size);
    if size == 0 {
        return Ok(None);
    }
    Ok(Some(data))
}

/// Client-side: read a fixed-length (`Content-Length`) body.
pub fn read_sized_body(
    stream: &mut TcpStream,
    headers: &HashMap<String, String>,
) -> Result<Vec<u8>, ReadError> {
    let len = headers
        .get("content-length")
        .and_then(|v| v.parse::<usize>().ok())
        .ok_or(ReadError::Malformed("response missing content-length"))?;
    if len > MAX_BODY_BYTES {
        return Err(ReadError::TooLarge);
    }
    let mut body = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match stream.read(&mut body[got..]) {
            Ok(0) => return Err(ReadError::Closed),
            Ok(n) => got += n,
            Err(e) => return Err(io_read_error(e)),
        }
    }
    Ok(body)
}

/// Has the peer hung up? Used between token polls so a client that drops
/// its connection mid-stream cancels the request instead of decoding to
/// completion into a dead socket. A live streaming client has nothing
/// left to send, so a successful zero-byte peek (orderly shutdown) or a
/// hard error (reset) both mean "gone"; `WouldBlock` means still there.
pub fn client_gone(stream: &TcpStream) -> bool {
    let mut buf = [0u8; 8];
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let gone = match stream.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    gone
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    /// Feed raw bytes to `read_request` through a loopback socket pair.
    fn parse(raw: &[u8]) -> Result<HttpRequest, ReadError> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut serverside, _) = listener.accept().unwrap();
        serverside
            .set_read_timeout(Some(Duration::from_millis(500)))
            .unwrap();
        client.write_all(raw).unwrap();
        drop(client); // EOF after the payload: Closed only if head short
        read_request(&mut serverside)
    }

    #[test]
    fn parses_request_with_body() {
        let req = parse(
            b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.body, b"abcd");
        assert_eq!(req.headers.get("host").map(String::as_str), Some("x"));
    }

    #[test]
    fn parses_bodyless_get() {
        let req = parse(b"GET /health HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/health");
        assert!(req.body.is_empty());
    }

    #[test]
    fn header_names_case_folded() {
        let req =
            parse(b"POST / HTTP/1.1\r\ncOnTeNt-LeNgTh: 2\r\n\r\nhi").unwrap();
        assert_eq!(req.body, b"hi");
    }

    #[test]
    fn rejects_non_http() {
        assert!(matches!(
            parse(b"hello there\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
    }

    #[test]
    fn rejects_bad_content_length() {
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
    }

    #[test]
    fn rejects_oversized_head() {
        let mut raw = b"GET /".to_vec();
        raw.extend(vec![b'a'; MAX_HEAD_BYTES + 1]);
        raw.extend_from_slice(b" HTTP/1.1\r\n\r\n");
        assert!(matches!(parse(&raw), Err(ReadError::TooLarge)));
    }

    #[test]
    fn rejects_oversized_body_before_reading_it() {
        let raw = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        assert!(matches!(parse(raw.as_bytes()), Err(ReadError::TooLarge)));
    }

    #[test]
    fn truncated_body_reports_closed() {
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"),
            Err(ReadError::Closed)
        ));
    }

    #[test]
    fn read_error_statuses() {
        assert!(read_error_status(&ReadError::Closed).is_none());
        assert_eq!(read_error_status(&ReadError::TimedOut).unwrap().0, 408);
        assert_eq!(read_error_status(&ReadError::TooLarge).unwrap().0, 413);
        assert_eq!(
            read_error_status(&ReadError::Malformed("x")).unwrap().0,
            400
        );
    }
}
