//! Front door: a minimal HTTP/1.1 serving edge over the coordinator.
//!
//! Hand-rolled on `std::net::TcpListener` (the offline build vendors no
//! HTTP crate): one acceptor thread feeds a small pool of connection
//! threads through a bounded queue, each connection carries exactly one
//! request (`Connection: close`). Routes:
//!
//! | route                      | behavior                                  |
//! |----------------------------|-------------------------------------------|
//! | `POST /v1/generate`        | submit; tokens stream back as chunked     |
//! |                            | ndjson, one `{"token":N}` line per chunk, |
//! |                            | then a terminal `{"done":true,...}` line  |
//! | `POST /v1/tenants`         | register a tenant from a JSON spec        |
//! | `DELETE /v1/tenants/<id>`  | remove a tenant                           |
//! | `GET /health`              | liveness + tenant count                   |
//! | `GET /metrics`             | [`Metrics::snapshot`] as JSON             |
//!
//! Cancellation is connection drop: between token polls the streamer
//! peeks the socket, and a hung-up client (or a failed chunk write)
//! triggers [`ResponseHandle::cancel`], returning the request's admission
//! slot and KV pages. [`ServeError`] variants map to status codes via
//! [`status_for`]. [`Frontend::shutdown`] stops accepting, then joins the
//! connection threads — in-flight streams drain to their terminal line
//! rather than being severed.
//!
//! [`Metrics::snapshot`]: crate::coordinator::Metrics::snapshot

pub mod http;

use crate::config::MethodCfg;
use crate::coordinator::{
    GenOptions, ResponseHandle, ServeError, Server, TenantSpec,
};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use http::{read_error_status, read_request, HttpRequest};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// Front-door tuning knobs. Defaults suit both the loopback tests and the
/// load-harness smoke runs.
#[derive(Debug, Clone)]
pub struct FrontendCfg {
    /// Connection-thread pool size.
    pub workers: usize,
    /// Accepted connections queued ahead of the pool; beyond this the
    /// acceptor sheds load with a best-effort 503.
    pub backlog: usize,
    /// Per-socket read/write timeout (request head+body on the way in,
    /// stalled clients on the way out).
    pub io_timeout: Duration,
    /// Token poll tick while streaming: bounds how quickly a client
    /// disconnect is noticed when no tokens are flowing.
    pub poll: Duration,
}

impl Default for FrontendCfg {
    fn default() -> FrontendCfg {
        FrontendCfg {
            workers: 4,
            backlog: 64,
            io_timeout: Duration::from_secs(5),
            poll: Duration::from_millis(20),
        }
    }
}

/// Map a [`ServeError`] to its HTTP status code.
pub fn status_for(e: &ServeError) -> u16 {
    match e {
        ServeError::UnknownTenant(_) => 404,
        ServeError::QueueFull { .. } => 429,
        ServeError::Deadline => 504,
        ServeError::Cancelled => 499,
        ServeError::ShuttingDown => 503,
        ServeError::Engine(_) => 500,
    }
}

/// Stable machine-readable tag for a [`ServeError`], carried in error
/// bodies and terminal stream lines next to the human-readable message.
pub fn error_kind(e: &ServeError) -> &'static str {
    match e {
        ServeError::UnknownTenant(_) => "unknown_tenant",
        ServeError::QueueFull { .. } => "queue_full",
        ServeError::Deadline => "deadline",
        ServeError::Cancelled => "cancelled",
        ServeError::ShuttingDown => "shutting_down",
        ServeError::Engine(_) => "engine",
    }
}

/// The running HTTP edge. Dropping it (or calling [`Frontend::shutdown`])
/// stops the acceptor and drains in-flight connections.
pub struct Frontend {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Frontend {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// serving `server` behind it.
    pub fn start(
        server: Arc<Server>,
        addr: &str,
        cfg: FrontendCfg,
    ) -> Result<Frontend> {
        assert!(cfg.workers > 0);
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("frontend bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<TcpStream>(cfg.backlog);
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let server = Arc::clone(&server);
            let cfg = cfg.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("frontend-{i}"))
                    .spawn(move || worker_loop(&rx, &server, &cfg))?,
            );
        }
        let stop2 = Arc::clone(&stop);
        let io_timeout = cfg.io_timeout;
        let acceptor = thread::Builder::new()
            .name("frontend-accept".into())
            .spawn(move || {
                accept_loop(&listener, &tx, &stop2, io_timeout);
            })?;
        Ok(Frontend {
            addr: local,
            stop,
            acceptor: Some(acceptor),
            workers,
        })
    }

    /// The bound address (resolves the actual port when started on `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting new connections and drain the in-flight ones: the
    /// acceptor exits and drops its queue sender, the pool finishes every
    /// queued and active connection (streams run to their terminal line),
    /// then the threads are joined. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Frontend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Accept until `stop`: hand sockets to the pool, shed with a best-effort
/// 503 once the backlog is full (a blocked acceptor would otherwise let
/// the kernel queue grow unbounded).
fn accept_loop(
    listener: &TcpListener,
    tx: &std::sync::mpsc::SyncSender<TcpStream>,
    stop: &AtomicBool,
    io_timeout: Duration,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_read_timeout(Some(io_timeout));
                let _ = stream.set_write_timeout(Some(io_timeout));
                match tx.try_send(stream) {
                    Ok(()) => {}
                    Err(TrySendError::Full(mut stream)) => {
                        let _ = respond_error(
                            &mut stream,
                            503,
                            "shedding",
                            "connection backlog full",
                        );
                    }
                    Err(TrySendError::Disconnected(_)) => return,
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// One pool thread: serve connections until the acceptor drops the queue.
fn worker_loop(
    rx: &Mutex<Receiver<TcpStream>>,
    server: &Server,
    cfg: &FrontendCfg,
) {
    loop {
        let conn = rx.lock().unwrap().recv();
        match conn {
            Ok(mut stream) => handle_conn(&mut stream, server, cfg),
            Err(_) => return,
        }
    }
}

fn respond_json(
    stream: &mut TcpStream,
    code: u16,
    body: &Json,
) -> std::io::Result<()> {
    http::write_response(
        stream,
        code,
        "application/json",
        body.to_string().as_bytes(),
    )
}

fn respond_error(
    stream: &mut TcpStream,
    code: u16,
    kind: &str,
    msg: &str,
) -> std::io::Result<()> {
    respond_json(
        stream,
        code,
        &Json::obj(vec![
            ("error", Json::str(msg)),
            ("kind", Json::str(kind)),
        ]),
    )
}

/// Parse, route, respond. Any panic would only take down this connection's
/// thread, but the routes below are panic-free by construction.
fn handle_conn(stream: &mut TcpStream, server: &Server, cfg: &FrontendCfg) {
    let req = match read_request(stream) {
        Ok(req) => req,
        Err(e) => {
            if let Some((code, msg)) = read_error_status(&e) {
                let _ = respond_error(stream, code, "bad_request", msg);
            }
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/generate") => route_generate(stream, server, cfg, &req),
        ("POST", "/v1/tenants") => route_register(stream, server, &req),
        ("DELETE", path) if path.starts_with("/v1/tenants/") => {
            route_remove(stream, server, &path["/v1/tenants/".len()..])
        }
        ("GET", "/health") => {
            let body = Json::obj(vec![
                ("status", Json::str("ok")),
                ("tenants", Json::num(server.tenant_ids().len() as f64)),
            ]);
            let _ = respond_json(stream, 200, &body);
        }
        ("GET", "/metrics") => {
            let _ = http::write_response(
                stream,
                200,
                "application/json",
                server.metrics.snapshot().to_string_pretty().as_bytes(),
            );
        }
        ("GET" | "POST" | "DELETE", p)
            if matches!(
                p,
                "/v1/generate" | "/v1/tenants" | "/health" | "/metrics"
            ) =>
        {
            let _ = respond_error(
                stream,
                405,
                "method_not_allowed",
                "wrong method for this route",
            );
        }
        _ => {
            let _ =
                respond_error(stream, 404, "no_such_route", "no such route");
        }
    }
}

/// Body for `POST /v1/generate`, all fields but `tenant`/`prompt`
/// optional: `max_new_tokens`, `temperature`, `top_k`, `seed`,
/// `deadline_ms`.
fn gen_options(body: &Json) -> GenOptions {
    let mut opts = GenOptions::greedy();
    if let Some(n) = body.get("max_new_tokens").and_then(Json::as_usize) {
        opts.max_new_tokens = n;
    }
    if let Some(t) = body.get("temperature").and_then(Json::as_f64) {
        opts.temperature = t as f32;
    }
    if let Some(k) = body.get("top_k").and_then(Json::as_usize) {
        opts.top_k = k;
    }
    if let Some(s) = body.get("seed").and_then(Json::as_f64) {
        opts.seed = s as u64;
    }
    if let Some(ms) = body.get("deadline_ms").and_then(Json::as_f64) {
        opts.deadline = Some(Duration::from_millis(ms as u64));
    }
    opts
}

fn route_generate(
    stream: &mut TcpStream,
    server: &Server,
    cfg: &FrontendCfg,
    req: &HttpRequest,
) {
    let body = match std::str::from_utf8(&req.body)
        .map_err(|_| ())
        .and_then(|s| Json::parse(s).map_err(|_| ()))
    {
        Ok(b) => b,
        Err(()) => {
            let _ = respond_error(
                stream,
                400,
                "bad_request",
                "body is not valid JSON",
            );
            return;
        }
    };
    let (Some(tenant), Some(prompt)) = (
        body.get("tenant").and_then(Json::as_str),
        body.get("prompt").and_then(Json::as_str),
    ) else {
        let _ = respond_error(
            stream,
            400,
            "bad_request",
            "body needs string fields 'tenant' and 'prompt'",
        );
        return;
    };
    let handle = match server.submit(tenant, prompt, gen_options(&body)) {
        Ok(h) => h,
        Err(e) => {
            let _ = respond_error(
                stream,
                status_for(&e),
                error_kind(&e),
                &e.to_string(),
            );
            return;
        }
    };
    stream_tokens(stream, &handle, cfg.poll);
}

/// Chunked ndjson streaming of one generation. Client disconnect (failed
/// chunk write, or a hang-up observed between polls) cancels the request.
fn stream_tokens(
    stream: &mut TcpStream,
    handle: &ResponseHandle,
    poll: Duration,
) {
    if http::start_chunked(stream, 200, "application/x-ndjson").is_err() {
        handle.cancel();
        return;
    }
    let send_line = |stream: &mut TcpStream, line: &Json| {
        let mut data = line.to_string();
        data.push('\n');
        http::write_chunk(stream, data.as_bytes())
    };
    let token_line =
        |tok: i32| Json::obj(vec![("token", Json::num(tok as f64))]);
    loop {
        match handle.recv_token_timeout(poll) {
            Some(tok) => {
                if send_line(stream, &token_line(tok)).is_err() {
                    handle.cancel();
                    return;
                }
            }
            None => {
                if let Some(result) = handle.try_wait() {
                    // tokens streamed before the resolution are already
                    // queued: drain them ahead of the terminal line
                    while let Some(tok) = handle.try_recv_token() {
                        if send_line(stream, &token_line(tok)).is_err() {
                            handle.cancel();
                            return;
                        }
                    }
                    let line = match result {
                        Ok(resp) => Json::obj(vec![
                            ("done", Json::Bool(true)),
                            ("id", Json::num(resp.id as f64)),
                            ("text", Json::str(resp.text)),
                            ("tokens", Json::num(resp.tokens as f64)),
                            (
                                "latency_ms",
                                Json::num(resp.latency.as_secs_f64() * 1e3),
                            ),
                        ]),
                        Err(e) => Json::obj(vec![
                            ("done", Json::Bool(true)),
                            ("error", Json::str(e.to_string())),
                            ("kind", Json::str(error_kind(&e))),
                        ]),
                    };
                    let _ = send_line(stream, &line);
                    let _ = http::end_chunked(stream);
                    return;
                }
                if http::client_gone(stream) {
                    handle.cancel();
                    return;
                }
            }
        }
    }
}

/// Body for `POST /v1/tenants`: `{"id": ..., "method": "mos"|"lora",
/// "r": 8, "l": 2, "e": 2, "private_rank": 1, "seed": 0}` — everything
/// but `id` optional, defaults shown. Scheduling-QoS fields (PR 9):
/// `"weight"` (DWRR share, ≥ 1) and `"rate_tok_per_s"` + `"burst"`
/// (token-bucket rate limit; `burst` defaults to one second of rate).
fn tenant_spec(body: &Json) -> Result<(String, TenantSpec)> {
    let id = body.req_str("id")?.to_string();
    let r = body.get("r").and_then(Json::as_usize).unwrap_or(8);
    let seed = body.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let spec = match body.get("method").and_then(Json::as_str).unwrap_or("mos")
    {
        "lora" => TenantSpec::lora(r),
        "mos" => {
            let l = body.get("l").and_then(Json::as_usize).unwrap_or(2);
            let e = body.get("e").and_then(Json::as_usize).unwrap_or(2);
            let p = body
                .get("private_rank")
                .and_then(Json::as_usize)
                .unwrap_or(1);
            TenantSpec::method(MethodCfg::mos(r, l, e, p))
        }
        other => return Err(anyhow!("unknown method '{other}'")),
    };
    let mut spec = spec.seed(seed);
    if let Some(w) = body.get("weight").and_then(Json::as_usize) {
        if w == 0 {
            return Err(anyhow!("weight must be >= 1"));
        }
        spec = spec.weight(w as u32);
    }
    if let Some(rate) = body.get("rate_tok_per_s").and_then(Json::as_f64) {
        if !(rate > 0.0) {
            return Err(anyhow!("rate_tok_per_s must be > 0"));
        }
        let burst = body
            .get("burst")
            .and_then(Json::as_f64)
            .unwrap_or(rate); // default: one second of rate
        spec = spec.rate_limit(rate, burst);
    }
    Ok((id, spec))
}

fn route_register(
    stream: &mut TcpStream,
    server: &Server,
    req: &HttpRequest,
) {
    let body = match std::str::from_utf8(&req.body)
        .ok()
        .and_then(|s| Json::parse(s).ok())
    {
        Some(b) => b,
        None => {
            let _ = respond_error(
                stream,
                400,
                "bad_request",
                "body is not valid JSON",
            );
            return;
        }
    };
    let (id, spec) = match tenant_spec(&body) {
        Ok(v) => v,
        Err(e) => {
            let _ =
                respond_error(stream, 400, "bad_request", &e.to_string());
            return;
        }
    };
    match server.register(&id, spec) {
        Ok(evicted) => {
            let body = Json::obj(vec![
                ("registered", Json::str(id)),
                (
                    "evicted",
                    Json::Arr(evicted.into_iter().map(Json::str).collect()),
                ),
            ]);
            let _ = respond_json(stream, 201, &body);
        }
        Err(e) => {
            let _ = respond_error(stream, 400, "register", &e.to_string());
        }
    }
}

fn route_remove(stream: &mut TcpStream, server: &Server, id: &str) {
    if server.remove(id) {
        let _ = respond_json(
            stream,
            200,
            &Json::obj(vec![("removed", Json::str(id))]),
        );
    } else {
        let _ = respond_error(
            stream,
            404,
            "unknown_tenant",
            &format!("no tenant '{id}'"),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::coordinator::{Admission, Registry, ServerCfg};

    /// Registry+server with no engine workers: enough for every route
    /// except a completed generation.
    fn edge(admission: Admission) -> (Arc<Server>, Frontend) {
        let mut cfg = presets::tiny();
        cfg.batch = 4;
        let registry = Arc::new(Registry::new(cfg, 1 << 30));
        let server = Arc::new(Server::new(
            registry,
            ServerCfg { admission, ..ServerCfg::default() },
        ));
        let fe = Frontend::start(
            Arc::clone(&server),
            "127.0.0.1:0",
            FrontendCfg {
                workers: 2,
                io_timeout: Duration::from_secs(2),
                ..FrontendCfg::default()
            },
        )
        .unwrap();
        (server, fe)
    }

    /// One-shot request helper: send `raw`, read status + JSON body.
    fn call(addr: SocketAddr, raw: String) -> (u16, Json) {
        use std::io::Write;
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let (status, headers) = http::read_response_head(&mut s).unwrap();
        let body = http::read_sized_body(&mut s, &headers).unwrap();
        let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        (status, json)
    }

    fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, Json) {
        call(
            addr,
            format!(
                "POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    fn get(addr: SocketAddr, path: &str) -> (u16, Json) {
        call(addr, format!("GET {path} HTTP/1.1\r\n\r\n"))
    }

    #[test]
    fn status_mapping_covers_every_variant() {
        let cases = [
            (ServeError::UnknownTenant("x".into()), 404, "unknown_tenant"),
            (ServeError::QueueFull { tenant: "x".into() }, 429, "queue_full"),
            (ServeError::Deadline, 504, "deadline"),
            (ServeError::Cancelled, 499, "cancelled"),
            (ServeError::ShuttingDown, 503, "shutting_down"),
            (ServeError::Engine("boom".into()), 500, "engine"),
        ];
        for (e, code, kind) in cases {
            assert_eq!(status_for(&e), code, "{e:?}");
            assert_eq!(error_kind(&e), kind, "{e:?}");
        }
    }

    #[test]
    fn health_metrics_register_remove_roundtrip() {
        let (_server, mut fe) = edge(Admission::default());
        let addr = fe.local_addr();

        let (code, body) = get(addr, "/health");
        assert_eq!(code, 200);
        assert_eq!(body.req_str("status").unwrap(), "ok");
        assert_eq!(body.req_usize("tenants").unwrap(), 0);

        let (code, body) =
            post(addr, "/v1/tenants", r#"{"id":"alice","seed":3}"#);
        assert_eq!(code, 201);
        assert_eq!(body.req_str("registered").unwrap(), "alice");
        assert!(body.get("evicted").unwrap().as_arr().unwrap().is_empty());

        let (code, body) = get(addr, "/health");
        assert_eq!(code, 200);
        assert_eq!(body.req_usize("tenants").unwrap(), 1);

        let (code, body) = get(addr, "/metrics");
        assert_eq!(code, 200);
        assert_eq!(body.req_usize("requests").unwrap(), 0);
        assert!(body.get("queue_depth").is_some());
        assert!(body.get("tenants").is_some());

        let (code, _) = call(
            addr,
            "DELETE /v1/tenants/alice HTTP/1.1\r\n\r\n".to_string(),
        );
        assert_eq!(code, 200);
        let (code, _) = call(
            addr,
            "DELETE /v1/tenants/alice HTTP/1.1\r\n\r\n".to_string(),
        );
        assert_eq!(code, 404);
        fe.shutdown();
    }

    #[test]
    fn register_with_qos_fields_installs_contract() {
        let (server, mut fe) = edge(Admission::default());
        let addr = fe.local_addr();
        let (code, _) = post(
            addr,
            "/v1/tenants",
            r#"{"id":"gold","weight":4,"rate_tok_per_s":500.0,"burst":64.0}"#,
        );
        assert_eq!(code, 201);
        let q = server.batcher.qos_of("gold").unwrap();
        assert_eq!(q.weight, 4);
        assert_eq!(q.rate_tok_per_s, Some(500.0));
        assert_eq!(q.burst, 64.0);
        // burst defaults to one second of rate
        let (code, _) = post(
            addr,
            "/v1/tenants",
            r#"{"id":"silver","rate_tok_per_s":200.0}"#,
        );
        assert_eq!(code, 201);
        assert_eq!(server.batcher.qos_of("silver").unwrap().burst, 200.0);
        // invalid contracts are 400s, not panics
        let (code, _) =
            post(addr, "/v1/tenants", r#"{"id":"bad","weight":0}"#);
        assert_eq!(code, 400);
        let (code, _) = post(
            addr,
            "/v1/tenants",
            r#"{"id":"bad","rate_tok_per_s":-1.0}"#,
        );
        assert_eq!(code, 400);
        fe.shutdown();
    }

    #[test]
    fn submit_errors_map_to_status_codes() {
        // per_tenant 1 so the second enqueued request rejects QueueFull
        let (server, mut fe) =
            edge(Admission { per_tenant: 1, global: 100 });
        let addr = fe.local_addr();

        let (code, body) = post(
            addr,
            "/v1/generate",
            r#"{"tenant":"ghost","prompt":"q:x"}"#,
        );
        assert_eq!(code, 404);
        assert_eq!(body.req_str("kind").unwrap(), "unknown_tenant");

        server.register("alice", TenantSpec::mos(4, 2, 2, 1)).unwrap();
        // no workers: this submit parks in the queue and holds the depth
        let held = server
            .submit("alice", "q:hold", GenOptions::greedy())
            .unwrap();
        let (code, body) = post(
            addr,
            "/v1/generate",
            r#"{"tenant":"alice","prompt":"q:over"}"#,
        );
        assert_eq!(code, 429);
        assert_eq!(body.req_str("kind").unwrap(), "queue_full");
        held.cancel();
        fe.shutdown();
    }

    #[test]
    fn bad_requests_rejected() {
        let (_server, mut fe) = edge(Admission::default());
        let addr = fe.local_addr();
        let (code, _) = post(addr, "/v1/generate", "not json");
        assert_eq!(code, 400);
        let (code, _) = post(addr, "/v1/generate", r#"{"tenant":"a"}"#);
        assert_eq!(code, 400);
        let (code, _) =
            post(addr, "/v1/tenants", r#"{"id":"x","method":"vera"}"#);
        assert_eq!(code, 400);
        let (code, body) = get(addr, "/nope");
        assert_eq!(code, 404);
        assert_eq!(body.req_str("kind").unwrap(), "no_such_route");
        let (code, _) = get(addr, "/v1/generate");
        assert_eq!(code, 405);
        fe.shutdown();
    }

    #[test]
    fn shutdown_idempotent_and_rebindable() {
        let (_server, mut fe) = edge(Admission::default());
        let addr = fe.local_addr();
        let (code, _) = get(addr, "/health");
        assert_eq!(code, 200);
        fe.shutdown();
        fe.shutdown(); // second call is a no-op
        assert!(TcpStream::connect(addr).is_err() || {
            // some platforms accept then reset; either way no service
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
            use std::io::Write;
            let _ = s.write_all(b"GET /health HTTP/1.1\r\n\r\n");
            http::read_response_head(&mut s).is_err()
        });
    }
}
