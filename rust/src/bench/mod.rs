//! Bench harness shared by all `benches/*.rs` binaries (criterion is not
//! vendored offline; benches are `harness = false` table printers).
//!
//! Every paper table/figure bench uses [`BenchCtx`]: it trains a method
//! config on synthetic tasks via the PJRT artifacts when available (fast:
//! XLA-compiled steps) and falls back to the host oracle otherwise, then
//! prints paper-value vs measured rows.
//!
//! Scale knobs (env): `MOS_BENCH_STEPS` (default 120), `MOS_BENCH_EVAL`
//! (default 24), `MOS_BENCH_SEEDS` (default 1), `MOS_BENCH_TASKS`
//! (default "recall,arith"), `MOS_BENCH_BACKEND` (auto|host|pjrt).

use crate::config::{MethodCfg, ModelCfg};
use crate::data::tasks::{Task, TaskKind};
use crate::runtime::{Manifest, Runtime};
use crate::train::host::HostBackend;
use crate::train::pjrt::PjrtBackend;
use crate::train::{run, RunResult};
use anyhow::Result;

/// Column-aligned table printer.
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate().take(ncol) {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            s.trim_end().to_string()
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Shared bench context.
pub struct BenchCtx {
    pub cfg: ModelCfg,
    pub preset: String,
    pub steps: usize,
    pub eval_n: usize,
    pub seeds: Vec<u64>,
    pub tasks: Vec<TaskKind>,
    pub lr: f64,
    runtime: Option<(Runtime, Manifest)>,
    force_host: bool,
}

impl BenchCtx {
    /// Standard context on the tiny preset.
    pub fn tiny() -> BenchCtx {
        BenchCtx::for_preset("tiny", crate::config::presets::tiny())
    }

    pub fn for_preset(preset: &str, cfg: ModelCfg) -> BenchCtx {
        let steps = env_usize("MOS_BENCH_STEPS", 120);
        let eval_n = env_usize("MOS_BENCH_EVAL", 24);
        let nseeds = env_usize("MOS_BENCH_SEEDS", 1);
        let tasks: Vec<TaskKind> = std::env::var("MOS_BENCH_TASKS")
            .unwrap_or_else(|_| "recall,arith".to_string())
            .split(',')
            .filter_map(TaskKind::parse)
            .collect();
        let backend =
            std::env::var("MOS_BENCH_BACKEND").unwrap_or_else(|_| "auto".into());
        let force_host = backend == "host";
        let runtime = if backend != "host" {
            let dir = Manifest::default_dir();
            match (Runtime::cpu(), Manifest::load(&dir)) {
                (Ok(rt), Ok(m)) if m.presets.contains_key(preset) => {
                    Some((rt, m))
                }
                _ => {
                    if backend == "pjrt" {
                        panic!(
                            "MOS_BENCH_BACKEND=pjrt but artifacts are \
                             missing (run `make artifacts`)"
                        );
                    }
                    None
                }
            }
        } else {
            None
        };
        BenchCtx {
            cfg,
            preset: preset.to_string(),
            steps,
            eval_n,
            seeds: (0..nseeds as u64).collect(),
            tasks,
            lr: 2e-2,
            runtime,
            force_host,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        if self.runtime.is_some() {
            "pjrt(artifacts)"
        } else {
            "host(oracle)"
        }
    }

    /// True if this method config has a lowered artifact available.
    fn has_artifact(&self, mc: &MethodCfg) -> bool {
        self.runtime
            .as_ref()
            .map(|(_, m)| {
                m.artifacts
                    .contains_key(&format!("train_{}_{}", mc.tag(), self.preset))
            })
            .unwrap_or(false)
    }

    /// Train + evaluate one (method, task, seed) cell.
    pub fn run_cell(
        &self,
        mc: &MethodCfg,
        kind: TaskKind,
        seed: u64,
    ) -> Result<RunResult> {
        let task_seed = seed; // task data varies with the seed, like resampled batches
        if !self.force_host && self.has_artifact(mc) {
            let (rt, m) = self.runtime.as_ref().unwrap();
            let mut be = PjrtBackend::load(rt, m, &self.preset, mc, seed)?;
            run(
                &mut be,
                || Task::new(kind, task_seed),
                self.steps,
                self.lr,
                self.eval_n,
                0,
            )
        } else {
            // host fallback: reuse the artifact bank's *pretrained* base
            // when geometry matches, so host and pjrt cells are comparable
            let mut be = match self.runtime.as_ref().and_then(|(_, m)| {
                if m.presets.get(&self.preset) == Some(&self.cfg) {
                    crate::util::bank::read_bank(&m.bank_path(&self.preset))
                        .ok()
                } else {
                    None
                }
            }) {
                Some(bank) => HostBackend::with_base(&self.cfg, mc, seed, bank),
                None => HostBackend::new(&self.cfg, mc, seed),
            };
            run(
                &mut be,
                || Task::new(kind, task_seed),
                self.steps,
                self.lr,
                self.eval_n,
                0,
            )
        }
    }

    /// Mean score across tasks and seeds; returns (per-task means, average,
    /// mean final loss, total train seconds).
    pub fn run_method(&self, mc: &MethodCfg) -> Result<MethodScores> {
        let mut per_task = Vec::new();
        let mut losses = Vec::new();
        let mut secs = 0.0;
        for &kind in &self.tasks {
            let mut scores = Vec::new();
            for &seed in &self.seeds {
                let r = self.run_cell(mc, kind, seed)?;
                scores.push(r.report.score);
                losses.push(crate::train::final_loss(&r.losses, 10));
                secs += r.train_seconds;
            }
            per_task.push(crate::stats::mean(&scores));
        }
        let avg = crate::stats::mean(&per_task);
        let loss = crate::stats::mean(&losses);
        Ok(MethodScores { per_task, avg, final_loss: loss, train_seconds: secs })
    }
}

#[derive(Debug, Clone)]
pub struct MethodScores {
    pub per_task: Vec<f64>,
    pub avg: f64,
    pub final_loss: f64,
    pub train_seconds: f64,
}

/// The paper-scaled method rows shared by the table benches (tiny preset;
/// budgets mirror Table 2's 5.00M/19.99M tiers scaled to e=2/e=8).
pub mod rows {
    use crate::config::MethodCfg;

    pub fn lora(r: usize) -> MethodCfg {
        MethodCfg::lora(r)
    }

    /// Main MoS at the 1x budget (paper "4/8" row): r=4e, l=2, private 1.
    pub fn mos_1x() -> MethodCfg {
        MethodCfg::mos(8, 2, 2, 1)
    }

    /// MoS at the 4x budget (paper "16/32" row).
    pub fn mos_4x() -> MethodCfg {
        MethodCfg::mos(16, 2, 8, 1)
    }

    pub fn mos_no_sp() -> MethodCfg {
        MethodCfg::mos(8, 2, 2, 0)
    }

    pub fn mos_no_vs() -> MethodCfg {
        MethodCfg::mos(8, 1, 2, 1)
    }

    pub fn mos_no_pd() -> MethodCfg {
        MethodCfg { pair_dissociation: false, ..MethodCfg::mos(8, 2, 2, 1) }
    }

    /// Sec. 2 pure sharing (rank = eL, identity routing).
    pub fn pure_sharing(blocks: usize) -> MethodCfg {
        MethodCfg::pure_sharing(2, blocks)
    }

    /// Sec. 2 pure sharing + random scaling.
    pub fn random_scaling(blocks: usize) -> MethodCfg {
        MethodCfg {
            random_scaling: true,
            ..MethodCfg::pure_sharing(2, blocks)
        }
    }

    /// Sec. 2 pure sharing + subset selection (r of eL, tied pairs, l=1).
    pub fn subset_selection() -> MethodCfg {
        MethodCfg {
            pair_dissociation: false,
            ..MethodCfg::mos(4, 1, 2, 0)
        }
    }

    pub fn vera() -> MethodCfg {
        MethodCfg::vera(16)
    }

    pub fn tied() -> MethodCfg {
        MethodCfg::tied(8)
    }

    pub fn prolora() -> MethodCfg {
        MethodCfg::prolora(8, 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_aligned() {
        let mut t = Table::new("demo", &["a", "method"]);
        t.row(vec!["1".into(), "lora".into()]);
        t.row(vec!["22".into(), "mos".into()]);
        t.print(); // smoke: no panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn row_configs_valid_on_tiny() {
        let cfg = crate::config::presets::tiny();
        for mc in [
            rows::lora(2),
            rows::mos_1x(),
            rows::mos_4x(),
            rows::mos_no_sp(),
            rows::mos_no_vs(),
            rows::mos_no_pd(),
            rows::pure_sharing(cfg.blocks),
            rows::random_scaling(cfg.blocks),
            rows::subset_selection(),
            rows::vera(),
            rows::tied(),
            rows::prolora(),
        ] {
            mc.validate(&cfg).unwrap();
        }
    }

    #[test]
    fn budget_tiers_match() {
        use crate::adapter::params::trainable_params;
        let cfg = crate::config::presets::tiny();
        let b1 = trainable_params(&cfg, &rows::lora(2));
        assert_eq!(trainable_params(&cfg, &rows::mos_1x()), b1);
        assert_eq!(trainable_params(&cfg, &rows::pure_sharing(cfg.blocks)), b1);
        assert_eq!(trainable_params(&cfg, &rows::subset_selection()), b1);
        assert_eq!(trainable_params(&cfg, &rows::prolora()), b1);
        assert_eq!(trainable_params(&cfg, &rows::mos_4x()), 4 * b1);
        assert_eq!(trainable_params(&cfg, &rows::lora(8)), 4 * b1);
    }
}
