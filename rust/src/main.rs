//! `mos` CLI — leader entrypoint for the framework.
//!
//! Subcommands:
//!   train     train one adapter on a synthetic task (pjrt or host backend)
//!   serve     multi-tenant serving demo, or (with --http) the HTTP edge
//!   traffic   replay a named seeded traffic shape (in-process or vs --http)
//!   eval      evaluate a checkpoint on a task
//!   params    parameter accounting / memory model on any geometry
//!   info      show manifest / artifact inventory
//!
//! Examples:
//!   mos train --preset tiny --method mos --r 8 --l 2 --e 2 --task recall
//!   mos serve --preset tiny --tenants 8 --http 127.0.0.1:8700
//!   mos traffic --shape cancel_storm --requests 64 --seed 0
//!   mos params --geometry llama2-7b
//!   mos info

use anyhow::{bail, Context, Result};
use mos::adapter::params::{fmt_bytes, fmt_params, multi_tenant_bytes, trainable_params};
use mos::config::{presets, Method, MethodCfg};
use mos::coordinator::{
    Admission, GenOptions, HostEngine, Registry, ServeError, Server, ServerCfg,
    TenantSpec,
};
use mos::data::tasks::{Task, TaskKind};
use mos::frontend::{Frontend, FrontendCfg};
use mos::loadgen::{
    register_tenants, register_tenants_http, run_shape, HttpClient,
    InProcessClient, Shape, TrafficCfg,
};
use mos::runtime::{Manifest, Runtime};
use mos::train::checkpoint::Checkpoint;
use mos::train::host::HostBackend;
use mos::train::pjrt::PjrtBackend;
use mos::train::{final_loss, run, Backend};
use mos::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    if args.has("verbose") {
        mos::util::log::set_level(mos::util::log::Level::Debug);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("traffic") => cmd_traffic(&args),
        Some("eval") => cmd_eval(&args),
        Some("params") => cmd_params(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "mos — Mixture of Shards multi-tenant adapter framework\n\n\
         USAGE: mos <train|serve|traffic|eval|params|info> [flags]\n\n\
         train:   --preset tiny --method mos --r 8 --l 2 --e 2 \
         [--private-rank 1] --task recall --steps 300 --lr 0.02 \
         [--backend auto|host|pjrt] [--seed 0] [--out ckpt_dir]\n\
         serve:   --preset tiny --tenants 8 --requests 64 \
         [--capacity-mb 64] [--workers 1] [--batch 8] [--max-wait-ms 5] \
         [--queue-per-tenant 256] [--queue-global 1024] \
         [--max-new-tokens N] [--temperature 0.0] [--top-k 0] \
         [--sample-seed 0] [--deadline-ms 0] \
         [--weights 1,2,4] [--rate-tok-s 0] [--burst R] \
         [--prefill-chunk 0] \
         [--http IP:PORT [--http-secs 0]]\n\
         \x20        with --http: serve the HTTP edge on IP:PORT instead of \
         running the demo loop\n\
         \x20        (POST /v1/generate streams ndjson; --http-secs 0 runs \
         until killed)\n\
         \x20        --weights cycles DWRR weights across tenants; \
         --rate-tok-s/--burst set a token-bucket per tenant; \
         --prefill-chunk N chunks long prefills (0 = one-shot)\n\
         traffic: --shape steady|bursty|diurnal|zipf|cancel_storm|\
         deadline_mix|weighted\n\
         \x20        [--shapes a,b,c] [--requests 32] [--seed 0] \
         [--tenants N] [--zipf-tenants 1200] [--prefill-chunk 0] \
         [--http IP:PORT] [--no-register]\n\
         \x20        replays seeded shapes in-process, or against a \
         running edge with --http; env fallbacks MOS_TRAFFIC_SHAPES/\
         REQS/SEED/ZIPF_TENANTS still honored\n\
         eval:    --ckpt ckpt_dir --task recall [--n 32]\n\
         params:  --geometry llama2-7b [--tenants 10000]\n\
         info:    [--artifacts DIR]"
    );
}

fn parse_method(args: &Args, blocks: usize) -> Result<MethodCfg> {
    let name = args.str("method", "mos");
    let r = args.usize("r", 8)?;
    let mut mc = match Method::parse(&name)? {
        Method::LoRA => MethodCfg::lora(r),
        Method::MoS => MethodCfg::mos(
            r,
            args.usize("l", 2)?,
            args.usize("e", 2)?,
            args.usize("private-rank", 1)?,
        ),
        Method::VeRA => MethodCfg::vera(r),
        Method::Tied => MethodCfg::tied(r),
        Method::PRoLoRA => MethodCfg::prolora(r, args.usize("m", 4)?),
    };
    if args.str("variant", "") == "pure" {
        mc = MethodCfg::pure_sharing(args.usize("e", 2)?, blocks);
    }
    Ok(mc)
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let cfg = presets::by_name(&preset)
        .with_context(|| format!("unknown preset {preset}"))?;
    let mc = parse_method(args, cfg.blocks)?;
    mc.validate(&cfg)?;
    let kind = TaskKind::parse(&args.str("task", "recall"))
        .context("unknown task")?;
    let steps = args.usize("steps", 300)?;
    let lr = args.f64("lr", 2e-2)?;
    let seed = args.u64("seed", 0)?;
    let eval_n = args.usize("eval-n", 32)?;
    let backend_kind = args.str("backend", "auto");

    println!(
        "train: preset={preset} method={} ({} trainable params) task={} steps={steps}",
        mc.tag(),
        fmt_params(trainable_params(&cfg, &mc)),
        kind.name()
    );

    let manifest_dir = Manifest::default_dir();
    let use_pjrt = match backend_kind.as_str() {
        "host" => false,
        "pjrt" => true,
        _ => manifest_dir.join("manifest.json").exists(),
    };

    let result = if use_pjrt {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(&manifest_dir)?;
        let mut be = PjrtBackend::load(&rt, &manifest, &preset, &mc, seed)?;
        let r = run(&mut be, || Task::new(kind, seed), steps, lr, eval_n, 25)?;
        maybe_save(args, &preset, &mc, seed, be.params().clone(), be.aux.clone())?;
        r
    } else {
        let mut be = HostBackend::new(&cfg, &mc, seed);
        let r = run(&mut be, || Task::new(kind, seed), steps, lr, eval_n, 25)?;
        maybe_save(
            args,
            &preset,
            &mc,
            seed,
            be.params().clone(),
            be.model.aux.clone(),
        )?;
        r
    };

    println!(
        "done: final_loss={:.4} {}={:.2} ({} eval examples) in {:.1}s",
        final_loss(&result.losses, 10),
        match result.report.metric {
            mos::data::tasks::Metric::F1 => "F1",
            mos::data::tasks::Metric::PassAt1 => "pass@1",
            _ => "EM",
        },
        result.report.score,
        result.report.n,
        result.train_seconds,
    );
    Ok(())
}

fn maybe_save(
    args: &Args,
    preset: &str,
    mc: &MethodCfg,
    seed: u64,
    params: mos::util::bank::Bank,
    aux: mos::util::bank::Bank,
) -> Result<()> {
    if let Some(dir) = args.get("out") {
        let ck = Checkpoint {
            preset: preset.to_string(),
            mc: mc.clone(),
            router_seed: seed,
            params,
            aux,
        };
        ck.save(std::path::Path::new(dir))?;
        println!("checkpoint saved to {dir}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let mut cfg = presets::by_name(&preset).context("unknown preset")?;
    cfg.batch = args.usize("batch", 8)?;
    let n_tenants = args.usize("tenants", 8)?;
    let n_requests = args.usize("requests", 64)?;
    let capacity = args.usize("capacity-mb", 64)? << 20;
    let workers = args.usize("workers", 1)?;

    // per-request generation options
    let temperature = args.f64("temperature", 0.0)? as f32;
    let mut opts = GenOptions::sample(
        temperature,
        args.usize("top-k", 0)?,
        args.u64("sample-seed", 0)?,
    )
    .max_new_tokens(args.usize("max-new-tokens", usize::MAX)?);
    let deadline_ms = args.u64("deadline-ms", 0)?;
    if deadline_ms > 0 {
        opts = opts.deadline(Duration::from_millis(deadline_ms));
    }

    // QoS contracts (PR 9): --weights cycles DWRR weights across the
    // registered tenants; --rate-tok-s/--burst arm every tenant's token
    // bucket; --prefill-chunk bounds prefill work per decode round.
    let weights: Vec<u32> = args
        .list("weights", &["1"])
        .iter()
        .map(|w| {
            w.parse::<u32>()
                .ok()
                .filter(|&w| w >= 1)
                .with_context(|| format!("--weights: bad weight '{w}'"))
        })
        .collect::<Result<_>>()?;
    if weights.is_empty() {
        bail!("--weights: need at least one weight");
    }
    let rate_tok_s = args.f64("rate-tok-s", 0.0)?;
    let burst = args.f64("burst", rate_tok_s)?;
    let prefill_chunk = match args.usize("prefill-chunk", 0)? {
        0 => None,
        n => Some(n),
    };

    let registry = Arc::new(Registry::new(cfg.clone(), capacity));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch: cfg.batch,
            max_wait: Duration::from_millis(args.u64("max-wait-ms", 5)?),
            cache_capacity: n_tenants.max(4),
            admission: Admission {
                per_tenant: args.usize("queue-per-tenant", 256)?,
                global: args.usize("queue-global", 1024)?,
            },
            prefill_chunk,
        },
    );
    for i in 0..n_tenants {
        let mut spec = TenantSpec::mos(8, 2, 2, 1)
            .seed(i as u64)
            .weight(weights[i % weights.len()]);
        if rate_tok_s > 0.0 {
            spec = spec.rate_limit(rate_tok_s, burst);
        }
        server.register(&format!("tenant-{i}"), spec)?;
    }
    println!(
        "registered {n_tenants} MoS tenants; ledger used {} of {}",
        fmt_bytes(registry.ledger.lock().unwrap().used()),
        fmt_bytes(capacity)
    );

    let cfg2 = cfg.clone();
    server.start(workers, move |_| HostEngine::new(cfg2.clone(), 0));

    // --http: expose the edge instead of running the demo loop
    if let Some(addr) = args.get("http") {
        let server = Arc::new(server);
        let mut fe =
            Frontend::start(Arc::clone(&server), addr, FrontendCfg::default())
                .context("starting HTTP edge")?;
        println!("http edge listening on {}", fe.local_addr());
        let secs = args.u64("http-secs", 0)?;
        if secs == 0 {
            // run until killed
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        std::thread::sleep(Duration::from_secs(secs));
        fe.shutdown();
        println!("{}", server.metrics.summary());
        return Ok(());
    }

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    let mut rejected = 0usize;
    for i in 0..n_requests {
        let tenant = format!("tenant-{}", i % n_tenants);
        match server.submit(&tenant, &format!("q:{:02}", i % 24), opts.clone()) {
            Ok(h) => handles.push(h),
            Err(e @ ServeError::QueueFull { .. }) => {
                rejected += 1;
                mos::debuglog!("shed: {e}");
            }
            Err(e) => return Err(e.into()),
        }
    }
    let mut ok = 0usize;
    let mut failed = 0usize;
    for h in handles {
        match h.wait_timeout(Duration::from_secs(120)) {
            Some(Ok(_)) => ok += 1,
            Some(Err(e)) => {
                failed += 1;
                mos::debuglog!("request failed: {e}");
            }
            None => anyhow::bail!("request timed out after 120s"),
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{n_requests} requests in {dt:.2}s ({:.1} req/s); \
         {failed} failed, {rejected} shed by admission control",
        ok as f64 / dt
    );
    println!("{}", server.metrics.summary());
    let (hits, misses) = server.cache.stats();
    println!("materialization cache: {hits} hits / {misses} builds");
    server.shutdown();
    Ok(())
}

/// CLI flag if given, else `env` var, else `default` — the PR-9
/// promotion of the traffic env knobs to proper flags.
fn knob_usize(
    args: &Args,
    flag: &str,
    env: &str,
    default: usize,
) -> Result<usize> {
    if args.has(flag) {
        return args.usize(flag, default);
    }
    Ok(std::env::var(env)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default))
}

/// Replay named seeded traffic shapes and print their `ShapeReport`s as
/// JSON (`--shape` for one, `--shapes a,b,c` for several — one JSON
/// object, or an array). In-process by default (a fresh tiny server per
/// shape, so shapes share no queue state); with `--http IP:PORT` it
/// drives a running edge instead (see `mos serve --http`), registering
/// the replay tenants over the wire first unless `--no-register` is
/// given. `MOS_TRAFFIC_SHAPES/REQS/SEED/ZIPF_TENANTS` are honored as
/// fallbacks for the matching flags.
fn cmd_traffic(args: &Args) -> Result<()> {
    let shapes_csv = args
        .get("shapes")
        .map(str::to_string)
        .or_else(|| std::env::var("MOS_TRAFFIC_SHAPES").ok())
        .unwrap_or_else(|| args.str("shape", "steady"));
    let shapes: Vec<Shape> = shapes_csv
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            Shape::parse(s).with_context(|| format!("unknown shape '{s}'"))
        })
        .collect::<Result<_>>()?;
    let requests = knob_usize(args, "requests", "MOS_TRAFFIC_REQS", 32)?;
    let seed = knob_usize(args, "seed", "MOS_TRAFFIC_SEED", 0)? as u64;
    let zipf_tenants =
        knob_usize(args, "zipf-tenants", "MOS_TRAFFIC_ZIPF_TENANTS", 1200)?;
    let prefill_chunk = match args.usize("prefill-chunk", 0)? {
        0 => None,
        n => Some(n),
    };

    let mut reports = Vec::new();
    for shape in &shapes {
        let mut tcfg = TrafficCfg::named(*shape, requests, seed);
        if *shape == Shape::Zipf {
            tcfg.tenants = zipf_tenants;
        }
        tcfg.tenants = args.usize("tenants", tcfg.tenants)?;

        let mut report = if let Some(addr) = args.get("http") {
            let addr: std::net::SocketAddr =
                addr.parse().context("--http wants IP:PORT")?;
            if !args.has("no-register") {
                register_tenants_http(addr, &tcfg)?;
            }
            run_shape(&tcfg, Arc::new(HttpClient::new(addr)))
        } else {
            let preset = args.str("preset", "tiny");
            let cfg = presets::by_name(&preset).context("unknown preset")?;
            let capacity = args.usize("capacity-mb", 1024)? << 20;
            let registry = Arc::new(Registry::new(cfg.clone(), capacity));
            let mut server = Server::new(
                registry,
                ServerCfg {
                    cache_capacity: tcfg.tenants.clamp(64, 2048),
                    prefill_chunk,
                    ..ServerCfg::default()
                },
            );
            let cfg2 = cfg.clone();
            server.start(args.usize("workers", 2)?, move |_| {
                HostEngine::new(cfg2.clone(), 0)
            });
            let server = Arc::new(server);
            register_tenants(&server, &tcfg)?;
            run_shape(
                &tcfg,
                Arc::new(InProcessClient::new(Arc::clone(&server))),
            )
        };
        if args.get("http").is_none() {
            report.prefill_chunk = prefill_chunk;
        }
        reports.push(report.to_json());
    }
    let out = if reports.len() == 1 {
        reports.pop().unwrap()
    } else {
        mos::util::json::Json::Arr(reports)
    };
    println!("{}", out.to_string_pretty());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let ckpt_dir = args.req("ckpt")?;
    let ck = Checkpoint::load(std::path::Path::new(ckpt_dir))?;
    let cfg = presets::by_name(&ck.preset).context("unknown preset")?;
    let kind =
        TaskKind::parse(&args.str("task", "recall")).context("unknown task")?;
    let n = args.usize("n", 32)?;
    let task = Task::new(kind, args.u64("seed", 0)?);

    let mut model = mos::model::HostModel::new(
        cfg.clone(),
        ck.mc.clone(),
        mos::model::transformer::init_base(&cfg, 0),
        ck.params,
        ck.aux,
    );
    let mut fwd = |tokens: &[i32]| model.forward(tokens);
    let rep = mos::eval::evaluate(&task, &mut fwd, n, cfg.batch, cfg.seq, cfg.vocab);
    println!("{}: score={:.2} em={:.2} (n={})", rep.task, rep.score, rep.em, rep.n);
    Ok(())
}

fn cmd_params(args: &Args) -> Result<()> {
    let geom = args.str("geometry", "llama2-7b");
    let cfg = presets::by_name(&geom).context("unknown geometry")?;
    let tenants = args.usize("tenants", 10_000)?;
    println!(
        "geometry {geom}: {} base params",
        fmt_params(cfg.base_param_count())
    );
    let rows: Vec<(&str, MethodCfg)> = vec![
        ("LoRA r=2", MethodCfg::lora(2)),
        ("LoRA r=8", MethodCfg::lora(8)),
        ("LoRA r=16", MethodCfg::lora(16)),
        ("LoRA r=64", MethodCfg::lora(64)),
        ("VeRA r=256", MethodCfg::vera(256)),
        ("Tied r=280", MethodCfg::tied(280)),
        ("PRoLoRA 4/8", MethodCfg::prolora(8, 4)),
        ("MoS 4/8 (e=2)", MethodCfg::mos(8, 2, 2, 1)),
        ("MoS 16/32 (e=8)", MethodCfg::mos(32, 2, 8, 1)),
    ];
    println!("{:<16} {:>10} {:>14}", "method", "# param", format!("{tenants} tenants"));
    for (name, mc) in rows {
        println!(
            "{:<16} {:>10} {:>14}",
            name,
            fmt_params(trainable_params(&cfg, &mc)),
            fmt_bytes(multi_tenant_bytes(&cfg, &mc, tenants, 2)),
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    if !dir.join("manifest.json").exists() {
        bail!(
            "no artifacts at {} — run `make artifacts` first",
            dir.display()
        );
    }
    let m = Manifest::load(&dir)?;
    println!("artifacts at {}:", dir.display());
    for (name, cfg) in &m.presets {
        println!(
            "  preset {name}: vocab={} hidden={} blocks={} seq={} batch={}",
            cfg.vocab, cfg.hidden, cfg.blocks, cfg.seq, cfg.batch
        );
    }
    for (name, art) in &m.artifacts {
        println!(
            "  {name}: kind={} inputs={} outputs={}",
            art.kind,
            art.inputs.len(),
            art.outputs.len()
        );
    }
    Ok(())
}
