//! Model/adapter/training configuration.
//!
//! `ModelCfg` mirrors `python/compile/model.py::ModelCfg` exactly (the
//! manifest is the source of truth at runtime; presets here are for
//! analytic work — parameter accounting, memory modelling — without
//! artifacts). LLaMA geometries are retained so the paper's "# Param"
//! column (Table 2) reproduces to the digit.

pub mod presets;

use crate::util::json::Json;
use anyhow::Result;

/// The seven linear-layer types the paper adapts (QLoRA convention).
pub const LAYER_TYPES: [&str; 7] = ["q", "k", "v", "o", "gate", "up", "down"];

/// Base transformer geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub blocks: usize,
    pub heads: usize,
    /// kv heads (GQA); == heads for MHA. LLaMA2-70B uses 8.
    pub kv_heads: usize,
    pub ff: usize,
    pub seq: usize,
    pub batch: usize,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// (out_features, in_features) for a layer type.
    pub fn dims(&self, layer_type: &str) -> (usize, usize) {
        let h = self.hidden;
        let kv = self.kv_heads * self.head_dim();
        match layer_type {
            "q" => (h, h),
            "k" | "v" => (kv, h),
            "o" => (h, h),
            "gate" | "up" => (self.ff, h),
            "down" => (h, self.ff),
            t => panic!("unknown layer type {t}"),
        }
    }

    /// Frozen base parameter count (tied embedding, norms, projections).
    pub fn base_param_count(&self) -> usize {
        let mut n = self.vocab * self.hidden + self.hidden;
        n += self.blocks * 2 * self.hidden;
        for t in LAYER_TYPES {
            let (o, i) = self.dims(t);
            n += self.blocks * o * i;
        }
        n
    }

    pub fn from_manifest(name: &str, j: &Json) -> Result<ModelCfg> {
        let heads = j.req_usize("heads")?;
        Ok(ModelCfg {
            name: name.to_string(),
            vocab: j.req_usize("vocab")?,
            hidden: j.req_usize("hidden")?,
            blocks: j.req_usize("blocks")?,
            heads,
            kv_heads: heads,
            ff: j.req_usize("ff")?,
            seq: j.req_usize("seq")?,
            batch: j.req_usize("batch")?,
        })
    }
}

/// Adaptation method family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    LoRA,
    MoS,
    VeRA,
    Tied,
    PRoLoRA,
}

impl Method {
    pub fn as_str(&self) -> &'static str {
        match self {
            Method::LoRA => "lora",
            Method::MoS => "mos",
            Method::VeRA => "vera",
            Method::Tied => "tied",
            Method::PRoLoRA => "prolora",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "lora" => Method::LoRA,
            "mos" => Method::MoS,
            "vera" => Method::VeRA,
            "tied" => Method::Tied,
            "prolora" => Method::PRoLoRA,
            _ => anyhow::bail!("unknown method '{s}'"),
        })
    }
}

/// Adapter geometry (mirrors python MethodCfg; see that docstring for field
/// semantics). For MoS, `private_rank` of the `r` rank slots per matrix are
/// routed to the private pool segment — a pure index-space convention that
/// needs no artifact change (paper Sec. 3.5).
#[derive(Debug, Clone, PartialEq)]
pub struct MethodCfg {
    pub method: Method,
    pub r: usize,
    pub l: usize,
    pub e: usize,
    pub m: usize,
    pub alpha: f64,
    pub private_rank: usize,
    /// MoS differentiation toggles (for ablations & the Sec. 2 schemes).
    pub pair_dissociation: bool,
    pub subset_selection: bool,
    /// Random per-rank scaling (Sec. 2 "Random Scaling"): frozen N(0,1)
    /// scalars folded into rank_scale instead of all-ones.
    pub random_scaling: bool,
}

impl MethodCfg {
    pub fn lora(r: usize) -> MethodCfg {
        MethodCfg {
            method: Method::LoRA,
            r,
            l: 1,
            e: 0,
            m: 1,
            alpha: 16.0,
            private_rank: 0,
            pair_dissociation: false,
            subset_selection: false,
            random_scaling: false,
        }
    }

    /// Full MoS with all four differentiation strategies on.
    pub fn mos(r: usize, l: usize, e: usize, private_rank: usize) -> MethodCfg {
        MethodCfg {
            method: Method::MoS,
            r,
            l,
            e,
            m: 1,
            alpha: 16.0,
            private_rank,
            pair_dissociation: true,
            subset_selection: true,
            random_scaling: false,
        }
    }

    pub fn vera(r: usize) -> MethodCfg {
        MethodCfg { method: Method::VeRA, r, ..MethodCfg::lora(r) }
    }

    pub fn tied(r: usize) -> MethodCfg {
        MethodCfg { method: Method::Tied, r, ..MethodCfg::lora(r) }
    }

    pub fn prolora(r: usize, m: usize) -> MethodCfg {
        MethodCfg { method: Method::PRoLoRA, r, m, ..MethodCfg::lora(r) }
    }

    /// The paper's "pure sharing" (Sec. 2): every block selects the whole
    /// pool in order; no dissociation, sharding, or privatization.
    pub fn pure_sharing(e: usize, blocks: usize) -> MethodCfg {
        MethodCfg {
            method: Method::MoS,
            r: e * blocks,
            l: 1,
            e,
            m: 1,
            alpha: 16.0,
            private_rank: 0,
            pair_dissociation: false,
            subset_selection: false,
            random_scaling: false,
        }
    }

    /// Shards per pool, budget-matched to LoRA rank `e` (see python
    /// MethodCfg.pool_shards): n = e * L * l.
    pub fn pool_shards(&self, blocks: usize) -> usize {
        self.e * blocks * self.l
    }

    /// Artifact tag (must match python MethodCfg.tag()).
    pub fn tag(&self) -> String {
        let mut bits = vec![self.method.as_str().to_string(), format!("r{}", self.r)];
        if self.method == Method::MoS {
            bits.push(format!("l{}", self.l));
            bits.push(format!("e{}", self.e));
        }
        if self.method == Method::PRoLoRA {
            bits.push(format!("m{}", self.m));
        }
        bits.join("_")
    }

    /// Validate against a model geometry.
    pub fn validate(&self, cfg: &ModelCfg) -> Result<()> {
        anyhow::ensure!(self.r > 0, "rank must be positive");
        if self.method == Method::MoS {
            anyhow::ensure!(self.l > 0 && self.e > 0, "mos needs l, e > 0");
            anyhow::ensure!(
                self.private_rank <= self.r,
                "private_rank {} > r {}",
                self.private_rank,
                self.r
            );
            for t in LAYER_TYPES {
                let (o, i) = cfg.dims(t);
                anyhow::ensure!(
                    i % self.l == 0 && o % self.l == 0,
                    "l={} does not divide dims of layer '{t}' ({o},{i})",
                    self.l
                );
            }
        }
        if self.method == Method::PRoLoRA {
            for t in LAYER_TYPES {
                let (o, i) = cfg.dims(t);
                anyhow::ensure!(
                    i % self.m == 0 && o % self.m == 0,
                    "m={} does not divide dims of layer '{t}' ({o},{i})",
                    self.m
                );
            }
        }
        Ok(())
    }
}

/// Training hyperparameters (paper Appendix A.2 scaled to our presets).
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f64,
    pub warmup_frac: f64,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 300,
            lr: 2e-3,
            warmup_frac: 0.03,
            seed: 0,
            log_every: 50,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelCfg {
        ModelCfg {
            name: "tiny".into(),
            vocab: 64,
            hidden: 64,
            blocks: 4,
            heads: 4,
            kv_heads: 4,
            ff: 160,
            seq: 48,
            batch: 16,
        }
    }

    #[test]
    fn dims_per_layer_type() {
        let c = tiny();
        assert_eq!(c.dims("q"), (64, 64));
        assert_eq!(c.dims("gate"), (160, 64));
        assert_eq!(c.dims("down"), (64, 160));
    }

    #[test]
    fn base_param_count_matches_python() {
        // python: tiny base_params is recorded in the manifest; the formula
        // here must agree: vocab*h + h + L*2h + L*sum(o*i)
        let c = tiny();
        let per_block = 4 * 64 * 64 + 2 * 160 * 64 + 64 * 160;
        let want = 64 * 64 + 64 + 4 * 2 * 64 + 4 * per_block;
        assert_eq!(c.base_param_count(), want);
    }

    #[test]
    fn tag_matches_python_convention() {
        assert_eq!(MethodCfg::lora(8).tag(), "lora_r8");
        assert_eq!(MethodCfg::mos(8, 2, 2, 2).tag(), "mos_r8_l2_e2");
        assert_eq!(MethodCfg::prolora(8, 4).tag(), "prolora_r8_m4");
    }

    #[test]
    fn pure_sharing_rank_is_el() {
        let mc = MethodCfg::pure_sharing(2, 4);
        assert_eq!(mc.r, 8);
        assert_eq!(mc.pool_shards(4), 8);
        assert!(!mc.subset_selection && !mc.pair_dissociation);
    }

    #[test]
    fn validate_rejects_bad_shard_count() {
        let c = tiny();
        // l=7 does not divide 64
        let mc = MethodCfg::mos(8, 7, 2, 0);
        assert!(mc.validate(&c).is_err());
        assert!(MethodCfg::mos(8, 2, 2, 0).validate(&c).is_ok());
        // private rank > r
        assert!(MethodCfg::mos(4, 2, 2, 5).validate(&c).is_err());
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::LoRA, Method::MoS, Method::VeRA, Method::Tied,
                  Method::PRoLoRA] {
            assert_eq!(Method::parse(m.as_str()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }
}
