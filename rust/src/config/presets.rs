//! Model geometry presets.
//!
//! `tiny`/`small`/`base` mirror python/compile/model.py::PRESETS (runtime
//! presets with artifacts). The LLaMA geometries are analytic-only: they let
//! `adapter::params` reproduce the paper's exact "# Param" column (Table 2)
//! and the intro's 3.36 TB multi-tenant memory claim.

use super::ModelCfg;

fn cfg(
    name: &str,
    vocab: usize,
    hidden: usize,
    blocks: usize,
    heads: usize,
    kv_heads: usize,
    ff: usize,
    seq: usize,
    batch: usize,
) -> ModelCfg {
    ModelCfg {
        name: name.into(),
        vocab,
        hidden,
        blocks,
        heads,
        kv_heads,
        ff,
        seq,
        batch,
    }
}

/// Runtime preset (has AOT artifacts).
pub fn tiny() -> ModelCfg {
    cfg("tiny", 64, 64, 4, 4, 4, 160, 48, 16)
}

/// Runtime preset (has AOT artifacts).
pub fn small() -> ModelCfg {
    cfg("small", 96, 256, 8, 8, 8, 688, 96, 8)
}

/// ~100M-parameter end-to-end preset (has AOT artifacts when built with
/// `make artifacts-base`).
pub fn base() -> ModelCfg {
    cfg("base", 2048, 768, 14, 12, 12, 2048, 64, 4)
}

/// LLaMA2-7B geometry (Touvron et al., 2023). Analytic only.
pub fn llama2_7b() -> ModelCfg {
    cfg("llama2-7b", 32000, 4096, 32, 32, 32, 11008, 4096, 1)
}

/// LLaMA2-13B geometry. Analytic only.
pub fn llama2_13b() -> ModelCfg {
    cfg("llama2-13b", 32000, 5120, 40, 40, 40, 13824, 4096, 1)
}

/// LLaMA2-70B geometry (GQA: 8 kv heads). Analytic only — used for the
/// intro's 3.36 TB serving-memory claim.
pub fn llama2_70b() -> ModelCfg {
    cfg("llama2-70b", 32000, 8192, 80, 64, 8, 28672, 4096, 1)
}

/// LLaMA3.2-3B geometry (Dubey et al., 2024; GQA: 8 kv heads). Analytic only.
pub fn llama32_3b() -> ModelCfg {
    cfg("llama3.2-3b", 128256, 3072, 28, 24, 8, 8192, 4096, 1)
}

pub fn by_name(name: &str) -> Option<ModelCfg> {
    Some(match name {
        "tiny" => tiny(),
        "small" => small(),
        "base" => base(),
        "llama2-7b" => llama2_7b(),
        "llama2-13b" => llama2_13b(),
        "llama2-70b" => llama2_70b(),
        "llama3.2-3b" => llama32_3b(),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_preset_is_about_100m() {
        let n = base().base_param_count();
        assert!(
            (90_000_000..115_000_000).contains(&n),
            "base preset has {n} params"
        );
    }

    #[test]
    fn llama2_7b_param_count_sane() {
        // LLaMA2-7B has ~6.7B params; our count (tied-embedding convention)
        // should land within a few percent of 6.6e9.
        let n = llama2_7b().base_param_count() as f64;
        assert!((6.3e9..7.0e9).contains(&n), "llama2-7b count {n}");
    }

    #[test]
    fn gqa_shrinks_kv() {
        let c = llama2_70b();
        let (o_k, i_k) = c.dims("k");
        assert_eq!(o_k, 8 * 128); // 8 kv heads * head_dim 128
        assert_eq!(i_k, 8192);
        let (o_q, _) = c.dims("q");
        assert_eq!(o_q, 8192);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["tiny", "small", "base", "llama2-7b", "llama2-13b",
                  "llama2-70b", "llama3.2-3b"] {
            assert_eq!(by_name(n).unwrap().name, n);
        }
        assert!(by_name("nope").is_none());
    }
}
