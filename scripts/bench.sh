#!/usr/bin/env bash
# Perf-trajectory runner: GEMM engine + serving benches with pinned knobs,
# writing BENCH_gemm.json / BENCH_serving.json at the repo root so every PR
# can append to the trajectory (ROADMAP.md §Perf).
#
# Usage: scripts/bench.sh
# Override any knob via the environment, e.g. MOS_THREADS=8 scripts/bench.sh
set -euo pipefail

cd "$(dirname "$0")/.."
export MOS_BENCH_OUT="$PWD"

# pinned knobs (override via env)
export MOS_THREADS="${MOS_THREADS:-$(nproc 2>/dev/null || echo 4)}"
export MOS_GEMM_MS="${MOS_GEMM_MS:-200}"
export MOS_SERVE_REQS="${MOS_SERVE_REQS:-48}"
export MOS_SERVE_TENANTS="${MOS_SERVE_TENANTS:-1,4,16}"
export MOS_TRAFFIC_REQS="${MOS_TRAFFIC_REQS:-32}"
export MOS_TRAFFIC_ZIPF_TENANTS="${MOS_TRAFFIC_ZIPF_TENANTS:-1200}"
export MOS_BENCH_BACKEND="${MOS_BENCH_BACKEND:-host}"

# the crate may live at the root or under rust/
MANIFEST_ARGS=""
if [ ! -f Cargo.toml ] && [ -f rust/Cargo.toml ]; then
    MANIFEST_ARGS="--manifest-path rust/Cargo.toml"
fi

# scalar control arm of the kernel sweep (info only): same engine with
# the explicit-SIMD microkernel pinned off via MOS_SIMD=0, written to
# BENCH_gemm_scalar.json so the simd-vs-scalar trajectory has a whole-run
# control next to the per-case simd_speedup_vs_scalar ratio
echo "== bench_gemm scalar control (MOS_SIMD=0, MOS_GEMM_MS=$MOS_GEMM_MS) =="
mkdir -p "$MOS_BENCH_OUT/.bench_scalar"
# shellcheck disable=SC2086
MOS_SIMD=0 MOS_BENCH_OUT="$MOS_BENCH_OUT/.bench_scalar" \
    cargo bench $MANIFEST_ARGS --bench bench_gemm
mv "$MOS_BENCH_OUT/.bench_scalar/BENCH_gemm.json" "$MOS_BENCH_OUT/BENCH_gemm_scalar.json"
rmdir "$MOS_BENCH_OUT/.bench_scalar"

echo "== bench_gemm (MOS_THREADS=$MOS_THREADS, MOS_GEMM_MS=$MOS_GEMM_MS) =="
# shellcheck disable=SC2086
cargo bench $MANIFEST_ARGS --bench bench_gemm

echo "== bench_serving (reqs=$MOS_SERVE_REQS, tenants=$MOS_SERVE_TENANTS) =="
# shellcheck disable=SC2086
cargo bench $MANIFEST_ARGS --bench bench_serving

echo "== bench_traffic (reqs/shape=$MOS_TRAFFIC_REQS, zipf tenants=$MOS_TRAFFIC_ZIPF_TENANTS) =="
# shellcheck disable=SC2086
cargo bench $MANIFEST_ARGS --bench bench_traffic

# same schema gate CI enforces: fail loud on a silently empty artifact.
# MOS_REQUIRE_SIMD=1 additionally gates the simd-vs-scalar headline (the
# baseline CI arm sets it; -Ctarget-cpu arms skip it because the scalar
# tile itself autovectorizes there)
SIMD_FLAG=""
if [ "${MOS_REQUIRE_SIMD:-0}" = "1" ]; then
    SIMD_FLAG="--require-simd-speedup"
fi
if command -v python3 >/dev/null 2>&1; then
    # shellcheck disable=SC2086
    python3 scripts/check_bench.py $SIMD_FLAG \
        "$MOS_BENCH_OUT/BENCH_gemm.json" "$MOS_BENCH_OUT/BENCH_serving.json" \
        "$MOS_BENCH_OUT/BENCH_traffic.json"
fi

echo "wrote $MOS_BENCH_OUT/BENCH_gemm.json, $MOS_BENCH_OUT/BENCH_serving.json and $MOS_BENCH_OUT/BENCH_traffic.json"
