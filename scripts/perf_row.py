#!/usr/bin/env python3
"""Render ROADMAP.md Perf-table rows from the bench JSON artifacts.

Usage:
  scripts/perf_row.py [BENCH_gemm.json] [--pr N]
  scripts/perf_row.py --serving [BENCH_serving.json] [--pr N]
  scripts/perf_row.py --traffic [BENCH_traffic.json] [--pr N]

Default mode prints the GEMM row matching the ROADMAP Perf table columns
(kernel is the runtime-dispatched microkernel the run selected; the simd
column is the min serving-scale speedup of that kernel over the scalar
tile pinned on the same pool — the PR-10 tentpole claim):
| PR | machine | threads | kernel | serving-scale GEMM speedup vs seed scalar (min) | geomean | simd vs scalar (min) |

--serving prints the serving-trajectory row (prefill ratio is
full_fwd_prefill p50 / lean p50 — the lean speedup, expect >> 1; the
adapter column is measured resident adapter MB at the largest tenant
count, pooled vs dense-materialized — the PR-6 memory claim; the kv
column is peak resident KV MB, paged pool vs fixed window, and the
warm/cold column is cold prefill p50 / warm shared-prefix prefill p50 —
both PR-7 claims; the int8 column is resident adapter+base MB of the
quantized tier vs the f32 pooled arm, and the accuracy column is the
measured max |dlogit| / top-1 agreement vs the f32 oracle — the PR-10
quantized-serving claim):
| PR | machine | kv/full tok/s | prefill p50 full/lean | ttft p50 ms (lean) | alloc MB lean vs full | adapter MB pooled vs dense | kv MB paged vs fixed | prefill p50 cold/warm | adapter+base MB int8 vs f32 | int8 max dlogit / top1 |

--traffic prints the traffic-trajectory row from the load-harness replay
(steady ttft p50/p99 is the uncontended baseline; the burst column shows
the chunked-prefill p99 vs its one-shot control arm — the PR-9 claim;
weighted is the DWRR contention shape's tail; zipf runs the 1k+ tenant
pooled tier; storm/deadline columns show the resolved-outcome mix of the
adversarial shapes):
| PR | machine | target | steady ttft p50/p99 ms | steady tok/s | burst ttft p99 ms chunked/1shot | weighted ttft p99 ms | zipf tenants | zipf ttft p99 ms | storm cxl/ok | deadline exp/ok chunked/1shot p99 |

CI appends the rows to the job summary and uploads the raw JSON as an
artifact; the next PR pastes the rows into ROADMAP.md.
"""
import json
import platform
import sys


def machine() -> str:
    return f"{platform.system()}-{platform.machine()}"


def pr_arg(default: str) -> str:
    if "--pr" in sys.argv:
        return sys.argv[sys.argv.index("--pr") + 1]
    return default


def gemm_row(path: str) -> str:
    with open(path) as f:
        bench = json.load(f)
    head = bench.get("headline", {})
    return "| {} | {} | {} | {} | {:.1f}x | {:.1f}x | {:.2f}x |".format(
        pr_arg("10 (simd+int8)"),
        machine(),
        int(bench.get("threads", 0)),
        bench.get("kernel", "?"),
        float(head.get("min_speedup_serving_scale", float("nan"))),
        float(head.get("geomean_speedup", float("nan"))),
        float(head.get("min_simd_speedup_serving_scale", float("nan"))),
    )


def serving_row(path: str) -> str:
    with open(path) as f:
        bench = json.load(f)
    cases = bench.get("cases", [])

    def pick(**want):
        rows = [c for c in cases if all(c.get(k) == v for k, v in want.items())]
        # largest tenant count = the most serving-like point of the sweep
        return max(rows, key=lambda c: c.get("tenants", 0)) if rows else None

    lean = pick(
        decode="kv_step",
        prefill="lean",
        max_batch=8,
        adapter="pooled",
        prefix="cold",
        kv="paged",
        prompts="uniq",
    )
    full_pre = pick(decode="kv_step", prefill="full_fwd_prefill", max_batch=8)
    full_fwd = pick(decode="full_fwd", max_batch=8)
    dense_ad = pick(
        decode="kv_step",
        prefill="lean",
        max_batch=8,
        adapter="dense",
        prefix="cold",
    )
    fixed_kv = pick(decode="kv_step", kv="fixed", prefill="lean", max_batch=8)
    warm = pick(decode="kv_step", kv="paged", prefix="warm", max_batch=8)
    # cold control for the warm ratio: the SAME shared-prefix prompt set
    # with sharing disabled, so the ratio isolates the COW prefix reuse
    cold_shared = pick(
        decode="kv_step", kv="paged", prefix="cold", prompts="shared", max_batch=8
    )
    int8_ad = pick(
        decode="kv_step",
        prefill="lean",
        max_batch=8,
        adapter="pooled_int8",
        prefix="cold",
    )
    acc = bench.get("int8_accuracy", {})

    def ratio(a, b, key):
        if not a or not b or not b.get(key):
            return float("nan")
        return a[key] / b[key]

    def val(c, key):
        return float(c.get(key, float("nan"))) if c else float("nan")

    return (
        "| {} | {} | {:.2f}x | {:.2f}x | {:.1f} | {:.0f} vs {:.0f} "
        "| {:.2f} vs {:.2f} | {:.3f} vs {:.3f} | {:.2f}x "
        "| {:.2f} vs {:.2f} | {:.3f}/{:.2f} |".format(
            pr_arg("10 (simd+int8)"),
            machine(),
            ratio(lean, full_fwd, "tok_per_s"),
            ratio(full_pre, lean, "prefill_p50_ms"),
            val(lean, "ttft_p50_ms"),
            val(lean, "alloc_mb"),
            val(full_pre, "alloc_mb"),
            val(lean, "adapter_mb"),
            val(dense_ad, "adapter_mb"),
            val(lean, "kv_mb"),
            val(fixed_kv, "kv_mb"),
            ratio(cold_shared, warm, "prefill_p50_ms"),
            val(int8_ad, "adapter_mb") + val(int8_ad, "base_mb"),
            val(lean, "adapter_mb") + val(lean, "base_mb"),
            float(acc.get("max_abs_dlogit", float("nan"))),
            float(acc.get("top1_agree", float("nan"))),
        )
    )


def traffic_row(path: str) -> str:
    with open(path) as f:
        bench = json.load(f)
    by_name = {s.get("shape"): s for s in bench.get("shapes", [])}

    def val(name, key):
        shape = by_name.get(name)
        return float(shape.get(key, float("nan"))) if shape else float("nan")

    return (
        "| {} | {} | {} | {:.1f}/{:.1f} | {:.0f} | {:.1f}/{:.1f} | {:.1f} "
        "| {} | {:.1f} | {:.0f}/{:.0f} | {:.0f}/{:.0f} ({:.1f}/{:.1f}) |".format(
            pr_arg("9 (scheduler QoS)"),
            machine(),
            bench.get("target", "?"),
            val("steady", "ttft_p50_ms"),
            val("steady", "ttft_p99_ms"),
            val("steady", "tok_per_s"),
            val("bursty", "ttft_p99_ms"),
            val("bursty", "ttft_p99_unchunked_ms"),
            val("weighted", "ttft_p99_ms"),
            int(val("zipf", "tenants")),
            val("zipf", "ttft_p99_ms"),
            val("cancel_storm", "cancelled"),
            val("cancel_storm", "completed"),
            val("deadline_mix", "expired"),
            val("deadline_mix", "completed"),
            val("deadline_mix", "ttft_p99_ms"),
            val("deadline_mix", "ttft_p99_unchunked_ms"),
        )
    )


def main() -> int:
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    # --pr consumes its value; drop it from the positional list
    if "--pr" in sys.argv:
        val = sys.argv[sys.argv.index("--pr") + 1]
        if val in args:
            args.remove(val)
    if "--serving" in sys.argv:
        print(serving_row(args[0] if args else "BENCH_serving.json"))
    elif "--traffic" in sys.argv:
        print(traffic_row(args[0] if args else "BENCH_traffic.json"))
    else:
        print(gemm_row(args[0] if args else "BENCH_gemm.json"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
