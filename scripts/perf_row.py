#!/usr/bin/env python3
"""Render a ROADMAP.md Perf-table row from BENCH_gemm.json.

Usage: scripts/perf_row.py [BENCH_gemm.json] [--pr N]

Prints the markdown row matching the ROADMAP Perf table columns:
| PR | machine | threads | serving-scale GEMM speedup vs seed scalar (min) | geomean |

CI appends this to the job summary and uploads the raw JSON as an
artifact; the next PR pastes the row into ROADMAP.md.
"""
import json
import platform
import sys


def main() -> int:
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    path = args[0] if args else "BENCH_gemm.json"
    pr = "2 (GEMM engine)"
    if "--pr" in sys.argv:
        pr = sys.argv[sys.argv.index("--pr") + 1]
    with open(path) as f:
        bench = json.load(f)
    head = bench.get("headline", {})
    machine = f"{platform.system()}-{platform.machine()}"
    row = "| {} | {} | {} | {:.1f}x | {:.1f}x |".format(
        pr,
        machine,
        int(bench.get("threads", 0)),
        float(head.get("min_speedup_serving_scale", float("nan"))),
        float(head.get("geomean_speedup", float("nan"))),
    )
    print(row)
    return 0


if __name__ == "__main__":
    sys.exit(main())
