//! Build-only stub of the vendored `xla` crate.
//!
//! The offline build image vendors a real PJRT-backed `xla` crate; public
//! CI has no access to it, so `scripts/ci_harness.sh` points the generated
//! Cargo.toml here instead. The contract:
//!
//! * host-side `Literal` handling is functional (the `runtime::pjrt` unit
//!   tests exercise shape/dtype binding round trips), and
//! * everything that would touch a real PJRT client fails at *runtime*
//!   with a clear error. The artifact-dependent integration tests skip
//!   themselves when `make artifacts` hasn't run, so tier-1 still passes.
//!
//! Only the API surface `rust/src/runtime/pjrt.rs` consumes is provided.

#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in the xla build stub (CI harness); \
         run inside the offline image with the real vendored xla crate"
    )))
}

/// Element types a `Literal` can hold.
pub trait NativeType: Copy {
    fn make_literal(data: &[Self]) -> Literal;
    fn read_literal(lit: &Literal) -> Option<&[Self]>;
}

#[derive(Debug, Clone)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn make_literal(data: &[f32]) -> Literal {
        Literal::F32 { data: data.to_vec(), dims: vec![data.len() as i64] }
    }
    fn read_literal(lit: &Literal) -> Option<&[f32]> {
        match lit {
            Literal::F32 { data, .. } => Some(data),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn make_literal(data: &[i32]) -> Literal {
        Literal::I32 { data: data.to_vec(), dims: vec![data.len() as i64] }
    }
    fn read_literal(lit: &Literal) -> Option<&[i32]> {
        match lit {
            Literal::I32 { data, .. } => Some(data),
            _ => None,
        }
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::make_literal(data)
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match self {
            Literal::F32 { data, .. } => data.len() as i64,
            Literal::I32 { data, .. } => data.len() as i64,
            Literal::Tuple(_) => {
                return Err(Error("cannot reshape a tuple literal".into()))
            }
        };
        if want != have {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elems) from {have} elems"
            )));
        }
        let mut out = self.clone();
        match &mut out {
            Literal::F32 { dims: d, .. } | Literal::I32 { dims: d, .. } => {
                *d = dims.to_vec()
            }
            Literal::Tuple(_) => unreachable!(),
        }
        Ok(out)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read_literal(self)
            .map(|d| d.to_vec())
            .ok_or_else(|| Error("literal dtype mismatch".into()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Ok(vec![other]),
        }
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }

    pub fn client(&self) -> PjRtClient {
        PjRtClient
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn tuple_unpacks() {
        let t = Literal::Tuple(vec![
            Literal::vec1(&[1i32]),
            Literal::vec1(&[2.0f32]),
        ]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn pjrt_paths_fail_loud() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
