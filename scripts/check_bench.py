#!/usr/bin/env python3
"""Fail the bench job when BENCH_*.json is missing expected keys.

Usage: scripts/check_bench.py [BENCH_gemm.json] [BENCH_serving.json]

Before this gate a silently empty/truncated JSON (bench crashed after
creating the file, schema drifted, env knob emptied the sweep) still
passed CI and the perf row rendered blank. Any missing file, empty case
list, or absent key is now a hard failure with a named culprit.
"""
import json
import sys

GEMM_TOP = ["bench", "threads", "kernel", "cases", "headline"]
GEMM_HEADLINE = [
    "min_speedup_serving_scale",
    "geomean_speedup",
    "min_simd_speedup_serving_scale",
]
GEMM_CASE = [
    "name",
    "m",
    "k",
    "n",
    "serving_scale",
    "seed_scalar_gflops",
    "blocked_1t_gflops",
    "blocked_mt_gflops",
    "kernel_scalar_gflops",
    "int8_gflops",
    "speedup_mt_vs_seed",
    "simd_speedup_vs_scalar",
    "int8_speedup_vs_f32",
]
# PR-10 tentpole gate (baseline CI arm only, via --require-simd-speedup):
# the explicit-SIMD microkernel must beat the pinned scalar tile at
# serving scale. Not applied on -Ctarget-cpu arms where the scalar tile
# itself autovectorizes to the same width.
MIN_SIMD_SPEEDUP = 1.3

SERVING_TOP = ["bench", "requests", "int8_accuracy", "cases"]
INT8_ACCURACY = ["max_abs_dlogit", "top1_agree", "budget_max_abs", "budget_top1"]
# int8 resident adapter+base bytes vs the matching f32 pooled arm
MAX_INT8_BYTES_RATIO = 0.35
SERVING_CASE = [
    "tenants",
    "decode",
    "prefill",
    "kv",
    "prefix",
    "prompts",
    "adapter",
    "max_batch",
    "req_per_s",
    "p50_ms",
    "p95_ms",
    "ttft_p50_ms",
    "prefill_p50_ms",
    "tok_per_s",
    "alloc_mb",
    "adapter_mb",
    "base_mb",
    "kv_mb",
]
TRAFFIC_TOP = ["bench", "seed", "requests_per_shape", "target", "shapes"]
TRAFFIC_SHAPE = [
    "shape",
    "requests",
    "tenants",
    "completed",
    "rejected",
    "expired",
    "cancelled",
    "errors",
    "ttft_p50_ms",
    "ttft_p99_ms",
    "latency_p50_ms",
    "latency_p99_ms",
    "tok_per_s",
    "duration_s",
]
# every named adversarial shape must be present in the replay
TRAFFIC_SHAPES = [
    "steady",
    "bursty",
    "diurnal",
    "zipf",
    "cancel_storm",
    "deadline_mix",
    "weighted",
]
# prefill-contended shapes must carry the chunked-vs-one-shot control
# arm (PR 9): the replay ran chunked, and records the one-shot p99
TRAFFIC_CHUNK_GATED = ["bursty", "deadline_mix"]
# QoS ceiling: chunked prefill must hold the bursty ttft tail within
# this factor of the uncontended steady baseline
MAX_BURSTY_OVER_STEADY_TTFT_P99 = 50.0

# the sweep must actually contain the arms the ROADMAP row compares
SERVING_ARMS = [
    {"decode": "kv_step", "prefill": "lean", "adapter": "pooled"},
    {"decode": "kv_step", "prefill": "lean", "adapter": "pooled_int8"},
    {"decode": "kv_step", "prefill": "lean", "adapter": "dense"},
    {"decode": "kv_step", "prefill": "full_fwd_prefill"},
    {"decode": "full_fwd"},
    {"decode": "kv_step", "kv": "paged", "prefix": "cold", "prompts": "uniq"},
    {"decode": "kv_step", "kv": "paged", "prefix": "cold", "prompts": "shared"},
    {"decode": "kv_step", "kv": "fixed"},
    {"decode": "kv_step", "kv": "paged", "prefix": "warm"},
]


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def require(obj: dict, keys: list, where: str) -> None:
    for k in keys:
        if k not in obj:
            fail(f"{where}: missing key '{k}' (has: {sorted(obj)})")


def load(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        fail(f"{path}: file not found (did the bench run?)")
    except json.JSONDecodeError as e:
        fail(f"{path}: invalid JSON ({e})")
    if not isinstance(data, dict):
        fail(f"{path}: top level is not an object")
    return data


def check_cases(path: str, data: dict, case_keys: list) -> list:
    cases = data.get("cases")
    if not isinstance(cases, list) or not cases:
        fail(f"{path}: 'cases' is empty or not a list")
    for i, case in enumerate(cases):
        if not isinstance(case, dict):
            fail(f"{path}: cases[{i}] is not an object")
        require(case, case_keys, f"{path}: cases[{i}]")
    return cases


def check_gemm(path: str, data: dict, require_simd: bool = False) -> None:
    require(data, GEMM_TOP, path)
    require(data["headline"], GEMM_HEADLINE, f"{path}: headline")
    check_cases(path, data, GEMM_CASE)
    if require_simd:
        if data["kernel"] == "scalar":
            fail(
                f"{path}: --require-simd-speedup set but the selected "
                f"kernel is scalar (MOS_SIMD pinned? unsupported CPU?)"
            )
        simd = data["headline"]["min_simd_speedup_serving_scale"]
        if not simd >= MIN_SIMD_SPEEDUP:
            fail(
                f"{path}: simd kernel '{data['kernel']}' is only "
                f"{simd:.2f}x the scalar tile at serving scale "
                f"(need >= {MIN_SIMD_SPEEDUP}x)"
            )
    print(f"check_bench: {path} ok ({len(data['cases'])} cases)")


def check_serving(path: str, data: dict) -> None:
    require(data, SERVING_TOP, path)
    cases = check_cases(path, data, SERVING_CASE)
    for arm in SERVING_ARMS:
        if not any(all(c.get(k) == v for k, v in arm.items()) for c in cases):
            fail(f"{path}: sweep is missing the {arm} arm")
    # int8 accuracy must sit inside the budget the bench recorded
    acc = data["int8_accuracy"]
    require(acc, INT8_ACCURACY, f"{path}: int8_accuracy")
    if not acc["max_abs_dlogit"] <= acc["budget_max_abs"]:
        fail(
            f"{path}: int8 max|dlogit| {acc['max_abs_dlogit']:.4f} over "
            f"budget {acc['budget_max_abs']}"
        )
    if not acc["top1_agree"] >= acc["budget_top1"]:
        fail(
            f"{path}: int8 top-1 agreement {acc['top1_agree']:.3f} under "
            f"budget {acc['budget_top1']}"
        )
    # int8 residency: adapter+base <= MAX_INT8_BYTES_RATIO x the f32
    # pooled arm it mirrors (same tenants / batch / mode fields)
    shape = ["tenants", "max_batch", "decode", "prefill", "kv", "prefix"]
    for c in cases:
        if c["adapter"] != "pooled_int8":
            continue
        twin = next(
            (
                f
                for f in cases
                if f["adapter"] == "pooled"
                and all(f[k] == c[k] for k in shape)
            ),
            None,
        )
        if twin is None:
            fail(f"{path}: pooled_int8 arm has no matching f32 pooled arm")
        got = c["adapter_mb"] + c["base_mb"]
        ref = twin["adapter_mb"] + twin["base_mb"]
        if not got <= ref * MAX_INT8_BYTES_RATIO:
            fail(
                f"{path}: int8 resident adapter+base {got:.3f}MB > "
                f"{MAX_INT8_BYTES_RATIO}x the f32 arm's {ref:.3f}MB"
            )
    print(f"check_bench: {path} ok ({len(cases)} cases)")


def check_traffic(path: str, data: dict) -> None:
    require(data, TRAFFIC_TOP, path)
    shapes = data.get("shapes")
    if not isinstance(shapes, list) or not shapes:
        fail(f"{path}: 'shapes' is empty or not a list")
    by_name = {}
    for i, shape in enumerate(shapes):
        if not isinstance(shape, dict):
            fail(f"{path}: shapes[{i}] is not an object")
        require(shape, TRAFFIC_SHAPE, f"{path}: shapes[{i}]")
        by_name[shape["shape"]] = shape
    for name in TRAFFIC_SHAPES:
        if name not in by_name:
            fail(f"{path}: replay is missing the '{name}' shape")
    for name, shape in by_name.items():
        resolved = (
            shape["completed"]
            + shape["rejected"]
            + shape["expired"]
            + shape["cancelled"]
            + shape["errors"]
        )
        if resolved != shape["requests"]:
            fail(
                f"{path}: {name}: {resolved} resolved != "
                f"{shape['requests']} requests"
            )
    # the paper-scale claim: a 1k+ tenant pooled tier absorbs the skewed
    # shape without eviction thrash (thrash surfaces as errors)
    zipf = by_name["zipf"]
    if zipf["tenants"] < 1000:
        fail(f"{path}: zipf ran only {zipf['tenants']} tenants (< 1000)")
    if zipf["errors"] != 0:
        fail(f"{path}: zipf replay had {zipf['errors']} errors")
    # PR-9 QoS gate: the weighted DWRR shape must resolve cleanly (a
    # rate/weight bug surfaces as errors or starved never-resolved rows)
    weighted = by_name["weighted"]
    if weighted["errors"] != 0:
        fail(f"{path}: weighted replay had {weighted['errors']} errors")
    # PR-9 chunked-prefill gate: the prefill-contended shapes ran with
    # chunking on and must show a strictly lower ttft p99 than their
    # one-shot control arm
    for name in TRAFFIC_CHUNK_GATED:
        shape = by_name[name]
        for key in ("prefill_chunk", "ttft_p99_unchunked_ms"):
            if key not in shape:
                fail(f"{path}: {name}: missing '{key}' (control arm not run?)")
        if not shape["prefill_chunk"]:
            fail(f"{path}: {name}: replay ran without chunked prefill")
        if not shape["ttft_p99_ms"] < shape["ttft_p99_unchunked_ms"]:
            fail(
                f"{path}: {name}: chunked ttft p99 "
                f"{shape['ttft_p99_ms']:.1f}ms is not below the one-shot "
                f"control {shape['ttft_p99_unchunked_ms']:.1f}ms"
            )
    # ... and hold the bursty tail within a fixed factor of steady
    steady_p99 = by_name["steady"]["ttft_p99_ms"]
    bursty_p99 = by_name["bursty"]["ttft_p99_ms"]
    if steady_p99 > 0 and bursty_p99 > steady_p99 * MAX_BURSTY_OVER_STEADY_TTFT_P99:
        fail(
            f"{path}: bursty ttft p99 {bursty_p99:.1f}ms exceeds "
            f"{MAX_BURSTY_OVER_STEADY_TTFT_P99:.0f}x the steady baseline "
            f"{steady_p99:.1f}ms"
        )
    print(f"check_bench: {path} ok ({len(shapes)} shapes)")


def main() -> int:
    args = sys.argv[1:]
    require_simd = "--require-simd-speedup" in args
    args = [a for a in args if a != "--require-simd-speedup"]
    args = args or ["BENCH_gemm.json", "BENCH_serving.json"]
    for path in args:
        data = load(path)
        # route on the artifact's own self-description, not the filename
        kind = data.get("bench")
        if kind == "serving":
            check_serving(path, data)
        elif kind == "gemm":
            check_gemm(path, data, require_simd)
        elif kind == "traffic":
            check_traffic(path, data)
        else:
            fail(f"{path}: unknown or missing 'bench' kind ({kind!r})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
